//! Incremental solve sessions: warm-start reuse across churn cycles.
//!
//! Algorithm 1 is invoked repeatedly over a live cluster — every
//! pending-pod fallback cycle and every defragmentation sweep — yet a
//! plain [`optimize`](super::algorithm::optimize) call rebuilds and
//! cold-solves every per-tier model from scratch, even when only one pod
//! arrived since the last solve. Long-running orchestration under churn
//! is exactly the regime where consecutive instances are near-identical,
//! so a [`SolveSession`] owned by the churn loop / fallback plugin keeps
//! three layers of reuse alive between solves:
//!
//! 1. **Full-state replay.** The session fingerprints the entire
//!    solve-relevant [`ClusterState`] (pods, nodes, bindings, statuses)
//!    plus `p_max` and the optimiser config. An unchanged fingerprint —
//!    the no-op delta — returns the previous run's result and optimality
//!    certificate without invoking the solver at all.
//! 2. **Per-solve / per-component replay.** A dirty state still shares
//!    most of its per-tier models with the previous cycle. Each phase
//!    solve routes through
//!    [`solve_portfolio_session`](crate::portfolio::solve_portfolio_session)
//!    with the session's [`SolveCache`]: solves (and, under
//!    decomposition, individual constraint-graph components) whose
//!    fingerprints are unchanged replay their cached *proven* solution
//!    and certificate; only dirty ones re-solve.
//! 3. **Warm-start floors.** Dirty solves project the previous incumbent
//!    onto the new model (via the hints Algorithm 1 already installs)
//!    and seed its objective as the portfolio's initial shared-incumbent
//!    floor, so racers prune from cycle one.
//!
//! # Determinism contract (non-negotiable)
//!
//! A session re-solve produces **byte-identical plans and objective
//! vectors** to a cold solve of the same state, at any thread count —
//! caching may only change *how fast* the answer arrives:
//!
//! * only *proven* (`Optimal` / `Infeasible`) results are ever cached or
//!   replayed — a proven result is a pure function of its model, so any
//!   completing cold solve reproduces it bit for bit;
//! * a full-state replay is only armed when the previous run was fully
//!   certified (every phase of every tier proven optimal);
//! * warm-start floors are feasible objective values pruned against
//!   *strictly*, which cannot change a completing solve's answer (see
//!   [`SharedIncumbent`](crate::solver::SharedIncumbent));
//! * any config change (knobs, modules, seed) clears the cache outright.
//!
//! The usual anytime caveat applies, same as the churn replay digests
//! and the portfolio's thread-independence: identity is guaranteed when
//! every solve completes within its window, which the incremental models
//! this layer exists for do in practice.
//!
//! Between solves the session also absorbs the state's event-log suffix
//! into a [`DeltaLog`] (arrivals, completions, drains, joins,
//! binds, evictions) — observability for churn reports, not a
//! correctness input: the fingerprint alone decides cleanliness.

use crate::cluster::{ClusterState, Event, NodeStatus, TaintEffect};
use crate::portfolio::{CacheStats, SolveCache};
use crate::solver::{Probe, SolveStatus};
use crate::telemetry::Telemetry;
use crate::util::fingerprint::Fnv64;

use super::algorithm::{optimize_probed, OptimizeResult, OptimizerConfig};

/// Cluster mutations observed between two session solves. Maintained by
/// scanning the state's event-log suffix (plus pod/node table growth),
/// so a driver never has to report deltas explicitly.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DeltaLog {
    /// Pods appended to the state's pod table (arrivals).
    pub arrivals: usize,
    /// Pods that reached end of life.
    pub completions: usize,
    /// Binds recorded (default scheduler + plan).
    pub binds: usize,
    /// Evictions recorded (all causes).
    pub evictions: usize,
    /// Nodes drained.
    pub drains: usize,
    /// Nodes joined.
    pub joins: usize,
}

impl DeltaLog {
    pub fn is_empty(&self) -> bool {
        *self == DeltaLog::default()
    }

    /// Total mutations observed.
    pub fn total(&self) -> usize {
        self.arrivals + self.completions + self.binds + self.evictions + self.drains + self.joins
    }
}

/// Session-level counters, surfaced through `ChurnResult` and the churn
/// report (cache-level counters live in [`CacheStats`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SessionStats {
    /// Calls to [`SolveSession::solve`].
    pub solves: u64,
    /// Calls that actually ran Algorithm 1 (misses).
    pub optimizer_runs: u64,
    /// Calls answered by full-state replay — the no-op delta path with
    /// zero solver invocations.
    pub full_hits: u64,
    /// Delta absorbed by the most recent solve call.
    pub last_delta: DeltaLog,
}

/// A long-lived incremental solve session (see module docs). Create one
/// per driver loop and hand it every re-solve of the same evolving
/// cluster; dropping it drops all cached certificates.
#[derive(Debug, Default)]
pub struct SolveSession {
    cache: SolveCache,
    /// Fingerprint of the config the cache was built under.
    cfg_fp: Option<u64>,
    /// Previous solve: state fingerprint and its fully certified result.
    last: Option<(u64, OptimizeResult)>,
    /// Event-log prefix already absorbed into the delta log.
    seen_events: usize,
    /// Pod-table length at the last absorption (arrivals counter).
    seen_pods: usize,
    delta: DeltaLog,
    pub stats: SessionStats,
}

impl SolveSession {
    pub fn new() -> Self {
        SolveSession::default()
    }

    /// Cache-level counters (solve/component hits, warm seeds).
    pub fn cache_stats(&self) -> &CacheStats {
        &self.cache.stats
    }

    /// Mutations observed since the last solve (resets on solve).
    pub fn pending_delta(&self) -> &DeltaLog {
        &self.delta
    }

    /// Run Algorithm 1 over `state`, reusing everything the session has
    /// proven since it was created. Result-equivalent to
    /// [`optimize`](super::algorithm::optimize) on the same inputs (see
    /// the module-level determinism contract).
    pub fn solve(
        &mut self,
        state: &ClusterState,
        p_max: u32,
        cfg: &OptimizerConfig,
    ) -> Option<OptimizeResult> {
        let local = Telemetry::from_verbosity(cfg.verbosity);
        self.solve_traced(state, p_max, cfg, &local)
    }

    /// [`solve`](Self::solve) with an explicit telemetry handle: the
    /// whole call sits in a `session` span (annotated with the absorbed
    /// delta and whether the full-state replay fired), and session
    /// counters land under `session_*`.
    pub fn solve_traced(
        &mut self,
        state: &ClusterState,
        p_max: u32,
        cfg: &OptimizerConfig,
        tel: &Telemetry,
    ) -> Option<OptimizeResult> {
        self.solve_probed(state, p_max, cfg, tel, &Probe::off())
    }

    /// [`solve_traced`](Self::solve_traced) with a solve-forensics
    /// [`Probe`]. A full-state replay answers without touching the
    /// solver, so it contributes nothing to the profile (by design: the
    /// profile reports *search* effort, and a replay performs none).
    pub fn solve_probed(
        &mut self,
        state: &ClusterState,
        p_max: u32,
        cfg: &OptimizerConfig,
        tel: &Telemetry,
        prof: &Probe,
    ) -> Option<OptimizeResult> {
        let sp = tel.span("session");
        self.stats.solves += 1;
        tel.add("session_solves_total", "", 1);
        self.absorb(state);
        self.stats.last_delta = std::mem::take(&mut self.delta);
        sp.arg("delta", self.stats.last_delta.total());

        let cfg_fp = fingerprint_config(cfg);
        if self.cfg_fp != Some(cfg_fp) {
            // Any knob change invalidates every cached certificate.
            self.cache.clear();
            self.last = None;
            self.cfg_fp = Some(cfg_fp);
        }

        let fp = fingerprint_state(state, p_max);
        if let Some((last_fp, res)) = &self.last {
            if *last_fp == fp {
                self.stats.full_hits += 1;
                tel.add("session_full_hits_total", "", 1);
                sp.arg("full_hit", true);
                tel.event("session", || {
                    "no-op delta: full-state replay, no solver invocation".to_string()
                });
                return Some(res.clone());
            }
        }

        self.stats.optimizer_runs += 1;
        tel.add("session_optimizer_runs_total", "", 1);
        let res = optimize_probed(state, p_max, cfg, Some(&mut self.cache), tel, prof);
        // Arm the full-state replay only with a fully certified run: an
        // anytime (deadline-truncated) result is not a pure function of
        // the state, so replaying it could diverge from a cold solve.
        self.last = match &res {
            Some(r) if fully_certified(r) => Some((fp, r.clone())),
            _ => None,
        };
        res
    }

    /// Absorb the state's event-log suffix into the delta log. Purely
    /// observational — robust to being handed a *different* state (the
    /// counters reset rather than underflow), since some drivers reuse
    /// one session across a dataset of independent instances.
    fn absorb(&mut self, state: &ClusterState) {
        let events = state.events.all();
        let start = self.seen_events.min(events.len());
        for e in &events[start..] {
            match e {
                Event::Bind { .. } | Event::PlanBind { .. } => self.delta.binds += 1,
                Event::Evict { .. } => self.delta.evictions += 1,
                Event::PodCompleted { .. } => self.delta.completions += 1,
                Event::NodeDrained { .. } => self.delta.drains += 1,
                Event::NodeJoined { .. } => self.delta.joins += 1,
                _ => {}
            }
        }
        self.seen_events = events.len();
        let pods = state.pods().len();
        self.delta.arrivals += pods.saturating_sub(self.seen_pods);
        self.seen_pods = pods;
    }
}

/// Every phase of every tier proven optimal — the precondition for
/// arming the full-state replay.
fn fully_certified(res: &OptimizeResult) -> bool {
    res.proved_optimal
        && res
            .tiers
            .iter()
            .all(|t| t.phase2_status == SolveStatus::Optimal)
}

/// Fingerprint everything Algorithm 1 reads from a [`ClusterState`]
/// (plus `p_max`). The event log and the virtual clock are history, not
/// solve input, and are deliberately excluded. A false *miss* is merely
/// slow; the field coverage below is what makes a false *hit*
/// impossible for distinct solve-relevant states (up to the 64-bit
/// collision odds discussed in [`crate::util::fingerprint`]).
pub fn fingerprint_state(state: &ClusterState, p_max: u32) -> u64 {
    let mut h = Fnv64::new();
    h.tag(b'T').write_u32(p_max);

    h.tag(b'N').write_usize(state.nodes().len());
    for node in state.nodes() {
        h.write_str(&node.name)
            .write_i64(node.capacity.cpu)
            .write_i64(node.capacity.ram);
        h.write_usize(node.labels.len());
        for (k, v) in &node.labels {
            h.write_str(k).write_str(v);
        }
        h.write_usize(node.taints.len());
        for t in &node.taints {
            h.write_str(&t.key).write_str(&t.value);
            // Exhaustive on purpose: a new effect variant must be hashed.
            match t.effect {
                TaintEffect::NoSchedule => h.tag(0),
            };
        }
        h.write_usize(node.extended.len());
        for (k, v) in &node.extended {
            h.write_str(k).write_i64(*v);
        }
        h.tag(match state.node_status(node.id) {
            NodeStatus::Ready => 0,
            NodeStatus::Cordoned => 1,
            NodeStatus::Removed => 2,
        });
    }

    h.tag(b'P').write_usize(state.pods().len());
    for pod in state.pods() {
        h.write_str(&pod.name)
            .write_i64(pod.request.cpu)
            .write_i64(pod.request.ram)
            .write_u32(pod.priority.0);
        match pod.owner {
            Some(rs) => h.tag(1).write_u32(rs),
            None => h.tag(0),
        };
        h.write_usize(pod.node_selector.len());
        for (k, v) in &pod.node_selector {
            h.write_str(k).write_str(v);
        }
        h.write_usize(pod.labels.len());
        for (k, v) in &pod.labels {
            h.write_str(k).write_str(v);
        }
        h.write_usize(pod.tolerations.len());
        for t in &pod.tolerations {
            h.write_str(&t.key);
            match &t.value {
                Some(v) => h.tag(1).write_str(v),
                None => h.tag(0),
            };
        }
        h.write_usize(pod.anti_affinity.len());
        for (k, v) in &pod.anti_affinity {
            h.write_str(k).write_str(v);
        }
        match pod.spread_max_skew {
            Some(s) => h.tag(1).write_i64(s),
            None => h.tag(0),
        };
        h.write_usize(pod.extended.len());
        for (k, v) in &pod.extended {
            h.write_str(k).write_i64(*v);
        }
        h.write_bool(state.is_retired(pod.id));
        match state.assignment_of(pod.id) {
            Some(n) => h.tag(1).write_u32(n.0),
            None => h.tag(0),
        };
    }
    h.finish()
}

/// Fingerprint the optimiser knobs a cached certificate depends on.
/// Modules contribute their [`ConstraintModule::fingerprint`] — which a
/// parameterized custom module must derive from its own configuration,
/// or the full-state replay cannot see the change; `threads` is
/// excluded (completed results are independent of the worker count)
/// while everything else conservatively invalidates on change.
///
/// [`ConstraintModule::fingerprint`]: super::constraints::ConstraintModule::fingerprint
fn fingerprint_config(cfg: &OptimizerConfig) -> u64 {
    let mut h = Fnv64::new();
    h.tag(b'C')
        .write_u64(cfg.total_timeout.as_nanos() as u64)
        .write_f64(cfg.alpha)
        .write_bool(cfg.incremental);
    let s = &cfg.solver;
    h.tag(b'S')
        .write_bool(s.use_bound)
        .write_bool(s.use_capacity_bound)
        .write_bool(s.use_hints)
        .write_bool(s.use_best_fit)
        .write_bool(s.use_symmetry)
        .write_bool(s.use_lns)
        .write_f64(s.lns_fraction)
        .write_bool(s.branch_easiest_first)
        .write_u64(s.check_interval)
        .write_u64(s.seed);
    h.tag(b'P')
        .write_bool(cfg.portfolio.decompose)
        .write_usize(cfg.portfolio.strategies);
    h.tag(b'M');
    for f in cfg.modules.fingerprints() {
        h.write_u64(f);
    }
    // Autoscale policy does not change what `optimize` returns for a
    // fixed state, but it is hashed anyway: conservatively invalidating
    // on any knob change is cheaper to reason about than carving out
    // exemptions field by field.
    h.tag(b'A');
    match &cfg.autoscale {
        None => h.tag(0),
        Some(a) => h.tag(1).write_u64(a.fingerprint()),
    };
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, NodeId, Pod, PodId, Priority, Resources};
    use crate::optimizer::algorithm::optimize;

    fn figure1() -> ClusterState {
        let nodes = identical_nodes(2, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(0)),
            Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        st
    }

    #[test]
    fn state_fingerprint_tracks_solve_relevant_mutations() {
        let st = figure1();
        let base = fingerprint_state(&st, 0);
        assert_eq!(base, fingerprint_state(&st.clone(), 0), "clone-stable");
        assert_ne!(base, fingerprint_state(&st, 1), "p_max is input");

        let mut bound = st.clone();
        bound.bind(PodId(2), NodeId(0)).unwrap();
        assert_ne!(base, fingerprint_state(&bound, 0), "binds are input");

        let mut grown = st.clone();
        grown.add_pod(Pod::new(0, "late", Resources::new(10, 10), Priority(0)));
        assert_ne!(base, fingerprint_state(&grown, 0), "arrivals are input");

        // The event log is history, not input: an extra recorded event
        // with no state change leaves the fingerprint alone.
        let mut logged = st.clone();
        logged.events.push(Event::SolverInvoked { pending: 1 });
        assert_eq!(base, fingerprint_state(&logged, 0));
    }

    #[test]
    fn noop_delta_replays_without_invoking_the_solver() {
        let st = figure1();
        let cfg = OptimizerConfig::with_timeout(5.0);
        let mut session = SolveSession::new();

        let first = session.solve(&st, 0, &cfg).expect("figure 1 solves");
        assert!(first.proved_optimal);
        assert_eq!(session.stats.optimizer_runs, 1);
        assert_eq!(session.stats.full_hits, 0);

        let replay = session.solve(&st, 0, &cfg).expect("replay");
        assert_eq!(session.stats.optimizer_runs, 1, "solver not invoked");
        assert_eq!(session.stats.full_hits, 1);
        assert_eq!(replay.target, first.target);
        assert_eq!(replay.placed_per_priority, first.placed_per_priority);
        assert!(replay.proved_optimal, "certificate replayed");
    }

    #[test]
    fn dirty_delta_resolves_and_matches_cold() {
        let mut st = figure1();
        let cfg = OptimizerConfig::with_timeout(5.0);
        let mut session = SolveSession::new();
        session.solve(&st, 0, &cfg).expect("first solve");

        st.add_pod(Pod::new(0, "pod-4", Resources::new(10, 512), Priority(0)));
        let warm = session.solve(&st, 0, &cfg).expect("re-solve");
        assert_eq!(session.stats.optimizer_runs, 2);
        assert_eq!(session.stats.last_delta.arrivals, 1);

        let cold = optimize(&st, 0, &cfg).expect("cold solve");
        assert_eq!(warm.target, cold.target);
        assert_eq!(warm.placed_per_priority, cold.placed_per_priority);
        assert_eq!(warm.proved_optimal, cold.proved_optimal);
    }

    #[test]
    fn config_change_clears_the_cache() {
        let st = figure1();
        let mut session = SolveSession::new();
        session
            .solve(&st, 0, &OptimizerConfig::with_timeout(5.0))
            .unwrap();
        // New seed = new certificates; the full-state replay must not fire.
        let mut cfg2 = OptimizerConfig::with_timeout(5.0);
        cfg2.solver.seed ^= 1;
        session.solve(&st, 0, &cfg2).unwrap();
        assert_eq!(session.stats.optimizer_runs, 2);
        assert_eq!(session.stats.full_hits, 0);
    }

    #[test]
    fn module_parameter_changes_invalidate_the_full_state_replay() {
        use crate::optimizer::builder::ModelCtx;
        use crate::optimizer::constraints::{ConstraintModule, ModuleRegistry};
        use crate::solver::Model;

        // A parameterized custom module folds its config into its cache
        // fingerprint; re-registering it with different parameters must
        // re-solve even though the state and module *name* are unchanged.
        struct Budget {
            cap: i64,
        }
        impl ConstraintModule for Budget {
            fn name(&self) -> &'static str {
                "Budget"
            }
            fn emit(&self, _ctx: &ModelCtx, _m: &mut Model) {}
            fn fingerprint(&self) -> u64 {
                Fnv64::new()
                    .write_str(self.name())
                    .write_i64(self.cap)
                    .finish()
            }
        }

        let st = figure1();
        let mut session = SolveSession::new();
        let with_cap = |cap| {
            OptimizerConfig::with_timeout(5.0)
                .with_modules(ModuleRegistry::standard().with(Budget { cap }))
        };
        let _ = session.solve(&st, 0, &with_cap(5));
        let _ = session.solve(&st, 0, &with_cap(2));
        assert_eq!(session.stats.optimizer_runs, 2, "parameter change re-solves");
        assert_eq!(session.stats.full_hits, 0);
        // and an unchanged parameter set does replay
        let _ = session.solve(&st, 0, &with_cap(2));
        assert_eq!(session.stats.full_hits, 1);
    }

    #[test]
    fn delta_log_attributes_mutations() {
        let mut st = figure1();
        let mut session = SolveSession::new();
        let cfg = OptimizerConfig::with_timeout(2.0);
        let _ = session.solve(&st, 0, &cfg);
        st.add_pod(Pod::new(0, "x", Resources::new(5, 5), Priority(0)));
        st.evict(PodId(0)).unwrap();
        assert!(session.pending_delta().is_empty(), "absorbed on solve only");
        let _ = session.solve(&st, 0, &cfg);
        let d = &session.stats.last_delta;
        assert_eq!(d.arrivals, 1);
        assert_eq!(d.evictions, 1);
        assert!(d.total() >= 2);
    }
}
