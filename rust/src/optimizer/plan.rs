//! Move plans: from a solver target to executable scheduling events.
//!
//! Kubernetes has no atomic multi-pod rebind (cross-node pre-emption API
//! is still under discussion — paper, "Kubernetes Plugin"). The paper's
//! plugin therefore executes the optimiser's placement as *separate
//! scheduling events*: evictions first, then (re)placements. Because the
//! target assignment is globally capacity-feasible, evicting every pod
//! that moves or leaves before binding anything guarantees each
//! subsequent bind fits.

use crate::cluster::{ClusterState, EvictCause, NodeId, PodId};

/// One pod's transition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PodMove {
    /// Pending → placed.
    Place { pod: PodId, to: NodeId },
    /// Placed → placed elsewhere (evict + rebind).
    Move { pod: PodId, from: NodeId, to: NodeId },
    /// Placed → pending (displaced by higher-priority packing).
    Displace { pod: PodId, from: NodeId },
}

/// An executable plan. `evictions` must run before `placements`.
#[derive(Clone, Debug, Default)]
pub struct MovePlan {
    /// Pods to evict first (moves + displacements).
    pub evictions: Vec<(PodId, NodeId)>,
    /// Pods to bind afterwards, with their target node, in priority order.
    pub placements: Vec<(PodId, NodeId)>,
    /// Full transition list (reporting / events).
    pub transitions: Vec<PodMove>,
}

impl MovePlan {
    /// Diff the live assignment against the solver target.
    pub fn build(state: &ClusterState, target: &[Option<NodeId>]) -> MovePlan {
        assert_eq!(target.len(), state.pods().len());
        let mut plan = MovePlan::default();
        for (i, pod) in state.pods().iter().enumerate() {
            let cur = state.assignment_of(pod.id);
            let tgt = target[i];
            match (cur, tgt) {
                (None, Some(to)) => {
                    plan.placements.push((pod.id, to));
                    plan.transitions.push(PodMove::Place { pod: pod.id, to });
                }
                (Some(from), Some(to)) if from != to => {
                    plan.evictions.push((pod.id, from));
                    plan.placements.push((pod.id, to));
                    plan.transitions.push(PodMove::Move { pod: pod.id, from, to });
                }
                (Some(from), None) => {
                    plan.evictions.push((pod.id, from));
                    plan.transitions.push(PodMove::Displace { pod: pod.id, from });
                }
                _ => {} // unchanged
            }
        }
        // Bind order: priority first (0 = highest), then id — determinism
        // and "higher priorities first" if anything goes wrong mid-plan.
        plan.placements
            .sort_by_key(|&(p, _)| (state.pod(p).priority, p));
        plan
    }

    /// Number of pods whose node changes (the paper's disruption metric).
    pub fn disruptions(&self) -> usize {
        self.transitions
            .iter()
            .filter(|t| matches!(t, PodMove::Move { .. } | PodMove::Displace { .. }))
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.transitions.is_empty()
    }

    /// Dry-run the plan on a clone, verifying every step. Returns the
    /// final utilisation on success.
    pub fn validate(&self, state: &ClusterState) -> Result<(f64, f64), String> {
        let mut sim = state.clone();
        self.execute(&mut sim)?;
        Ok(sim.utilization())
    }

    /// Execute against a state: all evictions, then all placements.
    /// Evictions are attributed to optimiser pre-emption; use
    /// [`execute_as`](MovePlan::execute_as) for sweep-driven plans.
    pub fn execute(&self, state: &mut ClusterState) -> Result<(), String> {
        self.execute_as(state, EvictCause::Preemption)
    }

    /// [`execute`](MovePlan::execute) with an explicit eviction
    /// attribution (the defragmentation sweep passes
    /// [`EvictCause::Sweep`] so the churn report can split disruption by
    /// driver).
    pub fn execute_as(&self, state: &mut ClusterState, cause: EvictCause) -> Result<(), String> {
        for &(pod, _) in &self.evictions {
            state
                .evict_as(pod, cause)
                .map_err(|e| format!("evict {pod:?}: {e}"))?;
        }
        for &(pod, node) in &self.placements {
            state
                .bind(pod, node)
                .map_err(|e| format!("bind {pod:?}->{node:?}: {e}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Resources};

    fn figure1_spread() -> ClusterState {
        let nodes = identical_nodes(2, Resources::new(4000, 4096));
        let pods = vec![
            Pod::new(0, "pod-1", Resources::new(10, 2048), Priority(0)),
            Pod::new(1, "pod-2", Resources::new(10, 2048), Priority(1)),
            Pod::new(2, "pod-3", Resources::new(10, 3072), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        st
    }

    #[test]
    fn builds_and_executes_figure1_plan() {
        let st = figure1_spread();
        // target: pods 0,1 together on node 0; pod 2 on node 1
        let target = vec![Some(NodeId(0)), Some(NodeId(0)), Some(NodeId(1))];
        let plan = MovePlan::build(&st, &target);
        assert_eq!(plan.evictions, vec![(PodId(1), NodeId(1))]);
        // placements sorted by priority: pod 2 (prio 0) before pod 1 (prio 1)
        assert_eq!(
            plan.placements,
            vec![(PodId(2), NodeId(1)), (PodId(1), NodeId(0))]
        );
        assert_eq!(plan.disruptions(), 1);
        let mut live = st.clone();
        plan.execute(&mut live).unwrap();
        live.check_invariants().unwrap();
        assert_eq!(live.assignment_of(PodId(1)), Some(NodeId(0)));
        assert_eq!(live.assignment_of(PodId(2)), Some(NodeId(1)));
    }

    #[test]
    fn evictions_always_precede_placements() {
        // Swap two pods across full nodes: only valid evict-first.
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(1000, 1000), Priority(0)),
            Pod::new(1, "b", Resources::new(1000, 1000), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        let target = vec![Some(NodeId(1)), Some(NodeId(0))];
        let plan = MovePlan::build(&st, &target);
        assert_eq!(plan.disruptions(), 2);
        plan.validate(&st).unwrap(); // would fail if binds ran first
    }

    #[test]
    fn empty_plan_for_identical_target() {
        let st = figure1_spread();
        let target: Vec<_> = st.assignment().to_vec();
        let plan = MovePlan::build(&st, &target);
        assert!(plan.is_empty());
        assert_eq!(plan.disruptions(), 0);
    }

    #[test]
    fn displacement_recorded() {
        let st = figure1_spread();
        let target = vec![None, Some(NodeId(1)), None];
        let plan = MovePlan::build(&st, &target);
        assert_eq!(plan.evictions.len(), 1);
        assert!(plan
            .transitions
            .iter()
            .any(|t| matches!(t, PodMove::Displace { pod, .. } if *pod == PodId(0))));
    }

    #[test]
    fn validate_rejects_bogus_target() {
        let st = figure1_spread();
        // Node 0 cannot hold all three pods' RAM (2048+2048+3072 > 4096).
        let target = vec![Some(NodeId(0)), Some(NodeId(0)), Some(NodeId(0))];
        let plan = MovePlan::build(&st, &target);
        assert!(plan.validate(&st).is_err());
    }
}
