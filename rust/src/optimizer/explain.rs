//! Placement explainer: why is this pod (still) pending?
//!
//! A certificate proves *that* a pod set is unplaceable; this module
//! says *why*, per node, in the constraint modules' own vocabulary. For
//! one pod it walks every ready node and reports the first rejection in
//! a fixed order — the static `admits` hooks in registration order
//! (selector, taint), then residual capacity per dimension against the
//! live free vector, then anti-affinity against the node's residents —
//! and tallies nodes per reason: "insufficient-ram on 12 nodes, taint
//! on 3, anti-affinity on 2". Nodes with no rejection count as
//! `feasible` (the pod is then pending for packing reasons — another
//! tier's pods hold the space — not hard infeasibility).
//!
//! Everything here is a read-only pure function of `ClusterState`, so
//! wiring it into the serve path (`explain` op) or the CLI (`--explain`)
//! can never perturb solve results.

use std::collections::BTreeMap;

use crate::cluster::{ClusterState, NodeId, PodId};
use crate::util::json::Json;

use super::constraints::ModuleRegistry;

/// Stable reason slug for a static-admits veto by the named module.
fn module_slug(name: &str) -> String {
    match name {
        "NodeSelector" => "selector".to_string(),
        "TaintsTolerations" => "taint".to_string(),
        other => other.to_string(),
    }
}

/// The first reason `pod` cannot (newly) land on `node`, or `None` when
/// the node would accept it right now.
pub fn node_rejection(
    state: &ClusterState,
    registry: &ModuleRegistry,
    pod: PodId,
    node: NodeId,
) -> Option<String> {
    let p = state.pod(pod);
    let n = state.node(node);
    for m in registry.modules() {
        if !m.admits(state, p, n) {
            return Some(module_slug(m.name()));
        }
    }
    let free = state.free(node);
    if p.request.cpu > free.cpu {
        return Some("insufficient-cpu".to_string());
    }
    if p.request.ram > free.ram {
        return Some("insufficient-ram".to_string());
    }
    // Extended dimensions, aggregated per resource name in name order.
    let mut ext: BTreeMap<&str, i64> = BTreeMap::new();
    for (k, amt) in &p.extended {
        *ext.entry(k.as_str()).or_insert(0) += amt;
    }
    for (k, amt) in ext {
        if amt > state.free_extended(node, k) {
            return Some(format!("insufficient-{k}"));
        }
    }
    for resident in state.pods_on(node) {
        let r = state.pod(resident);
        if p.anti_affine_with(r) || r.anti_affine_with(p) {
            return Some("anti-affinity".to_string());
        }
    }
    None
}

/// Per-node rejection census for one pod across every ready node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExplainReport {
    pub pod: PodId,
    /// Ready nodes inspected (`tally` totals + `feasible` == this).
    pub ready_nodes: usize,
    /// Ready nodes that would accept the pod right now.
    pub feasible: usize,
    /// Rejection reason → number of ready nodes vetoing for it.
    pub tally: BTreeMap<String, usize>,
    /// Per-node verdicts in node order (`None` = feasible).
    pub nodes: Vec<(NodeId, Option<String>)>,
}

impl ExplainReport {
    /// Wire/CLI form: `{"ready_nodes":N,"feasible":K,"reasons":{...}}`.
    /// Deterministic — reasons iterate in `BTreeMap` order.
    pub fn to_json(&self) -> Json {
        let mut reasons = Json::obj();
        for (reason, count) in &self.tally {
            reasons.set(reason, *count as u64);
        }
        let mut o = Json::obj();
        o.set("ready_nodes", self.ready_nodes as u64)
            .set("feasible", self.feasible as u64)
            .set("reasons", reasons);
        o
    }
}

/// Explain why `pod` is pending: walk every ready node through
/// [`node_rejection`] and tally. Covers **every** ready node — the
/// acceptance contract for certified-unplaceable pods.
pub fn explain_pod(state: &ClusterState, registry: &ModuleRegistry, pod: PodId) -> ExplainReport {
    let mut tally: BTreeMap<String, usize> = BTreeMap::new();
    let mut nodes = Vec::new();
    let mut ready = 0usize;
    let mut feasible = 0usize;
    for (j, _) in state.nodes().iter().enumerate() {
        let id = NodeId(j as u32);
        if !state.node_ready(id) {
            continue;
        }
        ready += 1;
        let verdict = node_rejection(state, registry, pod, id);
        match &verdict {
            None => feasible += 1,
            Some(reason) => *tally.entry(reason.clone()).or_insert(0) += 1,
        }
        nodes.push((id, verdict));
    }
    ExplainReport {
        pod,
        ready_nodes: ready,
        feasible,
        tally,
        nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Node, Pod, Priority, Resources, Taint};

    #[test]
    fn tallies_cover_every_ready_node() {
        // Three nodes: one tainted, one too small, one with a hostile
        // resident — the pending pod is rejected everywhere, each node
        // for a different reason.
        let mut nodes = identical_nodes(3, Resources::new(1000, 1000));
        nodes[0].taints.push(Taint::no_schedule("dedicated", "infra"));
        nodes[1] = Node::new(1, "node-1", Resources::new(100, 100));
        let pods = vec![
            Pod::new(0, "resident", Resources::new(10, 10), Priority(0)).with_label("app", "x"),
            Pod::new(1, "victim", Resources::new(200, 200), Priority(0))
                .with_anti_affinity("app", "x"),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(crate::cluster::PodId(0), NodeId(2)).unwrap();

        let reg = ModuleRegistry::standard();
        let report = explain_pod(&st, &reg, crate::cluster::PodId(1));
        assert_eq!(report.ready_nodes, 3);
        assert_eq!(report.feasible, 0);
        assert_eq!(report.tally.get("taint"), Some(&1));
        assert_eq!(report.tally.get("insufficient-cpu"), Some(&1));
        assert_eq!(report.tally.get("anti-affinity"), Some(&1));
        let total: usize = report.tally.values().sum();
        assert_eq!(total + report.feasible, report.ready_nodes);
        let j = report.to_json().to_string_compact();
        assert!(j.contains("\"taint\":1"));
    }

    #[test]
    fn feasible_nodes_report_no_reason() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![Pod::new(0, "p", Resources::new(10, 10), Priority(0))];
        let st = ClusterState::new(nodes, pods);
        let reg = ModuleRegistry::standard();
        let report = explain_pod(&st, &reg, crate::cluster::PodId(0));
        assert_eq!(report.feasible, 2);
        assert!(report.tally.is_empty());
        assert!(report.nodes.iter().all(|(_, r)| r.is_none()));
    }
}
