//! KWOK-like cluster simulator.
//!
//! The paper evaluates against *Kubernetes WithOut Kubelet* (KWOK): node
//! capacities and pod requests are simulated, no containers run, and the
//! real scheduling algorithm decides placements. [`kwok::KwokSimulator`]
//! is that harness over our scheduler re-implementation, configured the
//! way the paper forces determinism (lexicographic tie-break,
//! parallelism = 1, DefaultPreemption disabled).

pub mod kwok;

pub use kwok::{KwokSimulator, SimResult};
