//! The KWOK-style simulation driver.
//!
//! Feeds a workload (pods in ReplicaSet arrival order) through the
//! default scheduler against simulated node capacities and reports what
//! the paper's evaluation records: per-priority placement counts,
//! pending pods, and utilisation.

use crate::cluster::{ClusterState, Node, Pod, PodId};
use crate::scheduler::default::{BatchScorer, DefaultScheduler};

/// Result of one simulated scheduling pass.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub bound: usize,
    pub unschedulable: usize,
    /// Placed pods per priority tier (index = priority value).
    pub placed_per_priority: Vec<usize>,
    /// Pods left pending, in queue-park order.
    pub pending: Vec<PodId>,
    /// Mean (cpu, ram) utilisation over nodes in [0, 1].
    pub utilization: (f64, f64),
    /// True iff every pod was placed.
    pub all_placed: bool,
}

/// KWOK simulator: owns the scheduler; state is passed per run so callers
/// can replay/compare runs on cloned states.
pub struct KwokSimulator {
    scheduler: DefaultScheduler,
    p_max: u32,
}

impl KwokSimulator {
    /// Deterministic paper configuration.
    pub fn new(p_max: u32) -> Self {
        KwokSimulator {
            scheduler: DefaultScheduler::kwok_default(),
            p_max,
        }
    }

    /// Use an alternative scoring backend (e.g. the XLA runtime scorer).
    /// Mutates the existing scheduler in place, so any customisation
    /// applied through [`KwokSimulator::scheduler_mut`] beforehand (extra
    /// plugins, queue state) is preserved.
    pub fn with_batch_scorer(mut self, scorer: Box<dyn BatchScorer>) -> Self {
        self.scheduler.set_batch_scorer(scorer);
        self
    }

    pub fn scheduler_mut(&mut self) -> &mut DefaultScheduler {
        &mut self.scheduler
    }

    /// Build the initial state and schedule every pod (arrival order =
    /// pod id order = ReplicaSet generation order, exactly like feeding
    /// manifests to KWOK one after another).
    pub fn run(&mut self, nodes: Vec<Node>, pods: Vec<Pod>) -> (ClusterState, SimResult) {
        let mut state = ClusterState::new(nodes, pods);
        let result = self.run_on(&mut state);
        (state, result)
    }

    /// Schedule all currently-pending pods of an existing state.
    pub fn run_on(&mut self, state: &mut ClusterState) -> SimResult {
        self.scheduler.enqueue_pending(state);
        let stats = self.scheduler.run_queue(state);
        let pending = self.scheduler.queue.unschedulable_pods();
        SimResult {
            bound: stats.bound,
            unschedulable: stats.unschedulable,
            placed_per_priority: state.placed_per_priority(self.p_max),
            pending,
            utilization: state.utilization(),
            all_placed: stats.unschedulable == 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Priority, Resources};

    fn pods_spec(specs: &[(i64, i64, u32)]) -> Vec<Pod> {
        specs
            .iter()
            .enumerate()
            .map(|(i, &(cpu, ram, pr))| {
                Pod::new(i as u32, format!("pod-{i:03}"), Resources::new(cpu, ram), Priority(pr))
            })
            .collect()
    }

    #[test]
    fn schedules_everything_when_space_allows() {
        let mut sim = KwokSimulator::new(0);
        let (state, res) = sim.run(
            identical_nodes(2, Resources::new(4000, 4000)),
            pods_spec(&[(1000, 1000, 0), (1000, 1000, 0), (1000, 1000, 0)]),
        );
        assert!(res.all_placed);
        assert_eq!(res.placed_per_priority, vec![3]);
        state.check_invariants().unwrap();
        let (cpu, _) = res.utilization;
        assert!(cpu > 0.3);
    }

    #[test]
    fn figure1_scenario_strands_large_pod() {
        let mut sim = KwokSimulator::new(0);
        let (_, res) = sim.run(
            identical_nodes(2, Resources::new(100, 4096)),
            pods_spec(&[(10, 2048, 0), (10, 2048, 0), (10, 3072, 0)]),
        );
        assert!(!res.all_placed);
        assert_eq!(res.pending, vec![PodId(2)]);
        assert_eq!(res.bound, 2);
    }

    #[test]
    fn determinism_across_simulators() {
        let nodes = || identical_nodes(4, Resources::new(2000, 2000));
        let pods = || {
            pods_spec(&[
                (700, 300, 1),
                (900, 900, 0),
                (500, 1500, 2),
                (1200, 200, 0),
                (400, 400, 1),
            ])
        };
        let (s1, r1) = KwokSimulator::new(2).run(nodes(), pods());
        let (s2, r2) = KwokSimulator::new(2).run(nodes(), pods());
        assert_eq!(s1.assignment(), s2.assignment());
        assert_eq!(r1.placed_per_priority, r2.placed_per_priority);
    }

    #[test]
    fn with_batch_scorer_preserves_scheduler_customisation() {
        use crate::runtime::NativeScorer;
        use crate::scheduler::plugins::NodeResourcesFit;

        let mut sim = KwokSimulator::new(0);
        // customise the scheduler before installing the scorer ...
        sim.scheduler_mut()
            .framework
            .filter
            .push(Box::new(NodeResourcesFit));
        let filters_before = sim.scheduler_mut().framework.filter.len();
        assert_eq!(filters_before, 2); // kwok_default's + ours

        // ... the regression: this used to rebuild kwok_default(),
        // silently dropping the extra plugin.
        let mut sim = sim.with_batch_scorer(Box::new(NativeScorer));
        assert_eq!(sim.scheduler_mut().framework.filter.len(), filters_before);
        assert_eq!(sim.scheduler_mut().scorer_name(), "native");
    }

    #[test]
    fn run_on_existing_state_only_touches_pending() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = pods_spec(&[(600, 600, 0), (600, 600, 0)]);
        let mut state = ClusterState::new(nodes, pods);
        state.bind(PodId(0), crate::cluster::NodeId(1)).unwrap();
        let mut sim = KwokSimulator::new(0);
        let res = sim.run_on(&mut state);
        assert_eq!(res.bound, 1);
        // pod 1 cannot share node 1 with pod 0 → lands on node 0
        assert_eq!(state.assignment_of(PodId(1)), Some(crate::cluster::NodeId(0)));
    }
}
