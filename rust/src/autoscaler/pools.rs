//! Node pools: the shapes the autoscaler may provision from.
//!
//! A real cluster autoscaler does not conjure arbitrary machines — it
//! picks from a fixed menu of instance types (node groups / machine
//! sets), each with a capacity, optional device plugins (extended
//! resources), taints, labels, and a price. [`NodePool`] is that menu
//! entry. Capacities are expressed *relative* to a reference node
//! capacity (thousandths), because the paper's generator derives node
//! size from workload demand — a pool is "half a standard node", not
//! "2000 milli-CPU", so the same mix works across every grid cell.
//!
//! Pools serve two consumers:
//!
//! * the provisioning model ([`super::provision`]) offers candidate
//!   nodes drawn from each pool and pays `cost` per provisioned one;
//! * the workload generator's heterogeneous scenario family
//!   (`--node-pools small,large,gpu`) builds the *initial* fleet by
//!   cycling a mix, replacing the paper's identical-capacity assumption.

use crate::cluster::{Node, Resources, Taint};
use crate::util::fingerprint::Fnv64;

/// One provisionable node shape.
#[derive(Clone, Debug, PartialEq)]
pub struct NodePool {
    pub name: String,
    /// Capacity per dimension as thousandths of the reference capacity
    /// (1000 = one standard node). Applied with ceiling division so a
    /// pool never rounds below its intended share.
    pub scale_milli: i64,
    /// Extended (named) resource capacities every node of this pool
    /// offers, e.g. `[("gpu", 4)]`. Absolute, not scaled.
    pub extended: Vec<(String, i64)>,
    /// Taints stamped onto every provisioned node.
    pub taints: Vec<Taint>,
    /// Labels stamped onto every provisioned node.
    pub labels: Vec<(String, String)>,
    /// Cost per provisioned node, in abstract positive units — the
    /// provisioning objective minimises the cost sum first, node count
    /// second.
    pub cost: i64,
}

impl NodePool {
    pub fn new(name: impl Into<String>, scale_milli: i64, cost: i64) -> Self {
        assert!(scale_milli > 0, "pool scale must be positive");
        assert!(cost >= 1, "pool cost must be at least 1");
        NodePool {
            name: name.into(),
            scale_milli,
            extended: Vec::new(),
            taints: Vec::new(),
            labels: Vec::new(),
            cost,
        }
    }

    pub fn with_extended(mut self, resource: &str, amount: i64) -> Self {
        assert!(amount > 0, "extended capacity must be positive");
        self.extended.push((resource.to_string(), amount));
        self
    }

    pub fn with_taint(mut self, taint: Taint) -> Self {
        self.taints.push(taint);
        self
    }

    pub fn with_label(mut self, key: &str, value: &str) -> Self {
        self.labels.push((key.to_string(), value.to_string()));
        self
    }

    // ---- presets ----------------------------------------------------------

    /// Half a standard node. Cheapest per node; slightly cheaper per
    /// capacity unit than `large`, so pure cost optimisation prefers
    /// small nodes until the count phase tips the balance.
    pub fn small() -> NodePool {
        NodePool::new("small", 500, 5)
    }

    /// One-and-a-half standard nodes; economies of scale are deliberately
    /// *absent* (16 > 3 × 5 ÷ … is not: 16 vs 15 for 3× small capacity),
    /// so min-cost plans only pick `large` when packing demands it.
    pub fn large() -> NodePool {
        NodePool::new("large", 1500, 16)
    }

    /// A standard node carrying 4 GPUs — expensive, only worth
    /// provisioning for pods that actually request the device.
    pub fn gpu() -> NodePool {
        NodePool::new("gpu", 1000, 30).with_extended("gpu", 4)
    }

    /// The default provisioning menu: `small` + `large`.
    pub fn standard_mix() -> Vec<NodePool> {
        vec![NodePool::small(), NodePool::large()]
    }

    /// Parse one preset name (`small` | `large` | `gpu`).
    pub fn parse(name: &str) -> Option<NodePool> {
        match name.trim().to_ascii_lowercase().as_str() {
            "small" => Some(NodePool::small()),
            "large" => Some(NodePool::large()),
            "gpu" => Some(NodePool::gpu()),
            _ => None,
        }
    }

    /// Parse a comma-separated preset mix (`"small,large,gpu"`). `None`
    /// on the first unknown name; an empty string yields an empty mix.
    pub fn parse_mix(s: &str) -> Option<Vec<NodePool>> {
        if s.trim().is_empty() {
            return Some(Vec::new());
        }
        s.split(',').map(NodePool::parse).collect()
    }

    /// Render a mix back to its parseable `--node-pools` form
    /// (`"small,large"`) — deliberately named apart from the *report*
    /// rendering [`crate::autoscaler::report::mix_label`]
    /// (`"small x2 + gpu x1"`), which feeds byte-stable log lines and
    /// must never be confused with this spec string.
    pub fn mix_spec(pools: &[NodePool]) -> String {
        pools
            .iter()
            .map(|p| p.name.as_str())
            .collect::<Vec<_>>()
            .join(",")
    }

    // ---- instantiation ----------------------------------------------------

    /// Concrete capacity of one node of this pool, scaled from the
    /// reference (ceiling division — a pool never undercuts its share).
    pub fn capacity_for(&self, reference: Resources) -> Resources {
        let scale = |v: i64| -> i64 {
            if v <= 0 {
                0
            } else {
                (v * self.scale_milli + 999) / 1000
            }
        };
        Resources::new(scale(reference.cpu), scale(reference.ram))
    }

    /// A template [`Node`] of this pool (id/name are placeholders — the
    /// cluster's join path assigns real ones). Used both for
    /// admissibility checks against the constraint modules and as the
    /// shape handed to [`ClusterState::join_node_from`].
    ///
    /// [`ClusterState::join_node_from`]: crate::cluster::ClusterState::join_node_from
    pub fn node_template(&self, reference: Resources) -> Node {
        self.node_template_with_capacity(self.capacity_for(reference))
    }

    /// [`node_template`](NodePool::node_template) at an explicit
    /// capacity — churn traces carry the pre-computed capacity on their
    /// `Join` ops. The single place pool decorations (labels, taints,
    /// extended capacities) are stamped onto a node, so
    /// autoscaler-provisioned and trace-joined nodes of one pool can
    /// never drift apart.
    pub fn node_template_with_capacity(&self, capacity: Resources) -> Node {
        let mut node = Node::new(0, format!("pool-{}", self.name), capacity);
        for (k, v) in &self.labels {
            node = node.with_label(k, v);
        }
        for t in &self.taints {
            node = node.with_taint(t.clone());
        }
        for (k, v) in &self.extended {
            node = node.with_extended(k, *v);
        }
        node
    }

    /// Cache identity of this pool (all provisioning-relevant fields) —
    /// folded into [`AutoscaleConfig::fingerprint`].
    ///
    /// [`AutoscaleConfig::fingerprint`]: super::policy::AutoscaleConfig::fingerprint
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_str(&self.name)
            .write_i64(self.scale_milli)
            .write_i64(self.cost);
        h.write_usize(self.extended.len());
        for (k, v) in &self.extended {
            h.write_str(k).write_i64(*v);
        }
        h.write_usize(self.taints.len());
        for t in &self.taints {
            h.write_str(&t.key).write_str(&t.value);
        }
        h.write_usize(self.labels.len());
        for (k, v) in &self.labels {
            h.write_str(k).write_str(v);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_roundtrip() {
        let mix = NodePool::parse_mix("small,large,gpu").unwrap();
        assert_eq!(mix.len(), 3);
        assert_eq!(NodePool::mix_spec(&mix), "small,large,gpu");
        assert_eq!(NodePool::parse_mix("bogus"), None);
        assert_eq!(NodePool::parse_mix("").unwrap(), Vec::<NodePool>::new());
        // case/space tolerant
        assert_eq!(NodePool::parse(" GPU ").unwrap().name, "gpu");
    }

    #[test]
    fn capacity_scales_with_ceiling() {
        let reference = Resources::new(1001, 4096);
        let small = NodePool::small();
        // ceil(1001 * 500 / 1000) = 501
        assert_eq!(small.capacity_for(reference), Resources::new(501, 2048));
        let large = NodePool::large();
        assert_eq!(large.capacity_for(reference), Resources::new(1502, 6144));
    }

    #[test]
    fn gpu_template_carries_extended_capacity() {
        let t = NodePool::gpu().node_template(Resources::new(1000, 1000));
        assert_eq!(t.capacity, Resources::new(1000, 1000));
        assert_eq!(t.extended_capacity("gpu"), 4);
        assert_eq!(t.extended_capacity("tpu"), 0);
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let base = NodePool::small();
        assert_eq!(base.fingerprint(), NodePool::small().fingerprint());
        assert_ne!(base.fingerprint(), NodePool::large().fingerprint());
        let mut pricier = NodePool::small();
        pricier.cost += 1;
        assert_ne!(base.fingerprint(), pricier.fingerprint());
        let decorated = NodePool::small().with_extended("gpu", 1);
        assert_ne!(base.fingerprint(), decorated.fingerprint());
    }
}
