//! Certificate-guided scale-up: the min-cost provisioning CP model.
//!
//! When Algorithm 1 *proves* a priority tier's placement count maximal
//! and pods are still pending, those pods are certifiably unplaceable on
//! the current fleet — no amount of re-packing helps. This module turns
//! that infeasibility certificate into the cheapest fleet change that
//! makes the pending set placeable, as its own two-phase CP solve:
//!
//! * **Bins**: every Ready node's *spare* capacity (free CPU/RAM and
//!   extended residuals), plus up to `max_per_pool` candidate nodes per
//!   configured [`NodePool`].
//! * **Variables**: one placement variable per admissible (pod, bin)
//!   pair — admissibility reuses the optimiser's registered
//!   [`ConstraintModule`]s (selectors, taints vs. the pool's own taints,
//!   …) plus anti-affinity against residents — and one *shut-off*
//!   variable `z` per candidate (`z = 1` ⇔ the candidate is **not**
//!   provisioned).
//! * **Constraints**: every pod placed exactly once; per-bin knapsacks
//!   on every demanded dimension (candidate rows carry `cap·z` so a
//!   shut-off node offers zero capacity); pairwise anti-affinity among
//!   the pending pods on shared bins; and a per-pool prefix order on `z`
//!   (provisioned candidates are always ordinals `0..count`), which
//!   breaks the symmetry between identical candidates.
//! * **Phase A** maximises the *unspent* cost `Σ cost·z` (= minimise
//!   provisioned cost); the proven bound converts into a lower bound on
//!   any feasible plan's cost. **Phase B** locks phase A's metric
//!   (`=` when proven, `≥` otherwise — Algorithm 1's L8/L10 idiom) and
//!   maximises `Σ z` (= minimise node count).
//!
//! Both phases route through the parallel portfolio, so plans inherit
//! the PR 3 determinism contract: independent of the worker count
//! whenever the solves complete in-window, and `Optimal` statuses are
//! genuine optimality certificates — *min cost, then min node count*.

use crate::cluster::{ClusterState, Node, NodeId, PodId, Resources};
use crate::optimizer::constraints::ModuleRegistry;
use crate::portfolio::{solve_portfolio_traced, PortfolioConfig};
use crate::solver::{CmpOp, LinearExpr, Model, SolveStatus, SolverConfig, VarId};
use crate::telemetry::{Deadline, Telemetry};

use super::pools::NodePool;

/// Where a pending pod lands under a provisioning plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProvisionTarget {
    /// Spare capacity on an existing Ready node.
    Existing(NodeId),
    /// Candidate `ordinal` (0-based) of `pool` in the plan — resolved to
    /// a real [`NodeId`] when the plan is applied.
    New { pool: usize, ordinal: usize },
}

/// A provisioning plan with its optimality certificate.
#[derive(Clone, Debug)]
pub struct ProvisionPlan {
    /// Nodes to provision per pool, in configuration order (zero counts
    /// kept so indices line up with the pool list).
    pub per_pool: Vec<(String, usize)>,
    pub node_count: usize,
    /// Total cost of the provisioned nodes.
    pub cost: i64,
    /// Proven lower bound on the cost of *any* fleet change (within the
    /// candidate limits) that places the pod set; equals `cost` when
    /// `cost_status == Optimal`.
    pub cost_bound: i64,
    /// Phase A certificate: `Optimal` ⇔ `cost` is proven minimal.
    pub cost_status: SolveStatus,
    /// Phase B certificate: `Optimal` ⇔ `node_count` is proven minimal
    /// among min-cost plans.
    pub count_status: SolveStatus,
    /// A concrete feasible placement of every pod under the plan.
    pub placements: Vec<(PodId, ProvisionTarget)>,
}

impl ProvisionPlan {
    /// Both phases proven: the plan is certified *min cost, then min
    /// node count* — **for this pod set**, within the candidate limits.
    /// The certificate is conditional on the pods handed in: the packer
    /// proves the tier's placement *count* maximal and the leftover set
    /// is its (deterministic) choice among equal-count packings, so a
    /// joint re-pack-and-provision model could in principle host the
    /// tier more cheaply by leaving *different* pods pending (a ROADMAP
    /// follow-on).
    pub fn certified(&self) -> bool {
        self.cost_status == SolveStatus::Optimal && self.count_status == SolveStatus::Optimal
    }

    /// Human-readable pool mix, e.g. `"small x2 + gpu x1"` (`"none"`
    /// when the plan provisions nothing) — the same rendering the
    /// scale-up log line uses (see [`super::report::mix_label`]).
    pub fn mix_label(&self) -> String {
        super::report::mix_label(&self.per_pool)
    }

    /// Apply the plan: join the provisioned nodes (pool order, then
    /// ordinal order — deterministic names via the canonical join
    /// scheme) and bind every placement. All-or-nothing: the mutation
    /// runs on a log-detached trial clone first, so a failure leaves the
    /// live state untouched. Returns the joined node ids.
    pub fn apply(
        &self,
        state: &mut ClusterState,
        pools: &[NodePool],
        reference: Resources,
    ) -> Result<Vec<NodeId>, String> {
        let mut log = std::mem::take(&mut state.events);
        let mut trial = state.clone();
        match self.apply_inner(&mut trial, pools, reference) {
            Ok(ids) => {
                *state = trial;
                log.append(&mut state.events);
                state.events = log;
                Ok(ids)
            }
            Err(e) => {
                state.events = log;
                Err(e)
            }
        }
    }

    fn apply_inner(
        &self,
        state: &mut ClusterState,
        pools: &[NodePool],
        reference: Resources,
    ) -> Result<Vec<NodeId>, String> {
        if pools.len() < self.per_pool.len() {
            return Err("plan references more pools than configured".to_string());
        }
        let mut new_ids: Vec<Vec<NodeId>> = Vec::with_capacity(self.per_pool.len());
        for (p, (_, count)) in self.per_pool.iter().enumerate() {
            let template = pools[p].node_template(reference);
            new_ids.push(
                (0..*count)
                    .map(|_| state.join_node_from(&template))
                    .collect(),
            );
        }
        for &(pod, target) in &self.placements {
            let node = match target {
                ProvisionTarget::Existing(n) => n,
                ProvisionTarget::New { pool, ordinal } => *new_ids
                    .get(pool)
                    .and_then(|ids| ids.get(ordinal))
                    .ok_or_else(|| format!("placement references unprovisioned candidate ({pool},{ordinal})"))?,
            };
            state
                .bind(pod, node)
                .map_err(|e| format!("provision bind {pod:?} -> {node:?}: {e}"))?;
        }
        Ok(new_ids.into_iter().flatten().collect())
    }
}

/// Outcome of one provisioning solve.
#[derive(Clone, Debug)]
pub enum ProvisionOutcome {
    /// The cheapest fleet change found (possibly certified — see
    /// [`ProvisionPlan::certified`]).
    Plan(ProvisionPlan),
    /// Proven: even the maximum candidate fleet *within the configured
    /// limits* cannot place the pod set (a pod no pool admits, demand
    /// beyond every candidate's capacity, or not enough candidates under
    /// `max_per_pool`). The certificate covers the offered model, not
    /// the menu in the abstract — with a `max_per_pool` smaller than the
    /// pod count, raising it may still find a fleet.
    Infeasible,
    /// The deadline expired before any conclusion.
    Unknown,
}

/// One bin of the provisioning model.
enum Bin {
    Existing(NodeId),
    Candidate { pool: usize, ordinal: usize },
}

/// Solve the min-cost provisioning model for `pods` (pending pods the
/// caller believes unplaceable — typically
/// [`certified_unplaceable`](super::policy::certified_unplaceable)).
/// `reference` is the capacity the pool scales apply to;
/// `max_per_pool` bounds the candidates offered per pool (further
/// clamped to the pod count — a minimal plan never provisions more
/// nodes than pods).
///
/// Topology spread is *not* encoded here (skew couples pending pods
/// with placed owner-group mates fleet-wide); the scale-up trigger
/// filters spread-constrained pods out before they reach this solve.
#[allow(clippy::too_many_arguments)]
pub fn plan_provisioning(
    state: &ClusterState,
    pods: &[PodId],
    pools: &[NodePool],
    reference: Resources,
    max_per_pool: usize,
    deadline: Deadline,
    solver: &SolverConfig,
    portfolio: &PortfolioConfig,
    modules: &ModuleRegistry,
    tel: &Telemetry,
) -> ProvisionOutcome {
    let sp = tel.span("provision");
    sp.arg("pods", pods.len());
    tel.add("autoscaler_provision_solves_total", "", 1);
    if pods.is_empty() {
        return ProvisionOutcome::Plan(ProvisionPlan {
            per_pool: pools.iter().map(|p| (p.name.clone(), 0)).collect(),
            node_count: 0,
            cost: 0,
            cost_bound: 0,
            cost_status: SolveStatus::Optimal,
            count_status: SolveStatus::Optimal,
            placements: Vec::new(),
        });
    }

    // ---- bins --------------------------------------------------------------
    // `max_per_pool == 0` offers no candidates at all: the solve then
    // covers existing spare capacity only, and a pod nothing admits is
    // proven Infeasible-within-limits — "provisioning disabled", not a
    // silent one-node floor.
    let per_pool_candidates = max_per_pool.min(pods.len());
    let mut bins: Vec<Bin> = Vec::new();
    let mut bin_nodes: Vec<Node> = Vec::new(); // template per bin (admits checks)
    for node in state.nodes() {
        if state.node_ready(node.id) {
            bins.push(Bin::Existing(node.id));
            bin_nodes.push(node.clone());
        }
    }
    let first_candidate = bins.len();
    for (p, pool) in pools.iter().enumerate() {
        let template = pool.node_template(reference);
        for k in 0..per_pool_candidates {
            bins.push(Bin::Candidate { pool: p, ordinal: k });
            bin_nodes.push(template.clone());
        }
    }

    // Extended dimensions any of the pods demand (sorted, deduplicated).
    let mut dims: Vec<&str> = pods
        .iter()
        .flat_map(|&p| state.pod(p).extended.iter())
        .filter(|(_, amt)| *amt > 0)
        .map(|(k, _)| k.as_str())
        .collect();
    dims.sort_unstable();
    dims.dedup();

    // ---- variables ---------------------------------------------------------
    let mut m = Model::new();
    // x[pod_idx][bin] — None marks an inadmissible pair.
    let mut x: Vec<Vec<Option<VarId>>> = Vec::with_capacity(pods.len());
    for &pod_id in pods {
        let pod = state.pod(pod_id);
        let per_bin: Vec<Option<VarId>> = bins
            .iter()
            .enumerate()
            .map(|(b, bin)| {
                let node = &bin_nodes[b];
                if !modules.admits(state, pod, node) {
                    return None;
                }
                let fits = match bin {
                    Bin::Existing(id) => {
                        // Spare capacity + resident anti-affinity, the
                        // same vocabulary ClusterState::bind enforces.
                        pod.request.fits_in(&state.free(*id))
                            && pod
                                .extended
                                .iter()
                                .all(|(k, amt)| state.free_extended(*id, k) >= *amt)
                            && state.pods_on(*id).iter().all(|&q| {
                                let other = state.pod(q);
                                !(pod.anti_affine_with(other) || other.anti_affine_with(pod))
                            })
                    }
                    Bin::Candidate { .. } => {
                        pod.request.fits_in(&node.capacity)
                            && pod
                                .extended
                                .iter()
                                .all(|(k, amt)| node.extended_capacity(k) >= *amt)
                    }
                };
                fits.then(|| m.new_var())
            })
            .collect();
        if per_bin.iter().all(Option::is_none) {
            // No bin — existing or candidate — admits this pod: proven
            // infeasible before the solver even runs.
            return ProvisionOutcome::Infeasible;
        }
        x.push(per_bin);
    }
    // z[candidate] — 1 ⇔ the candidate is NOT provisioned.
    let z: Vec<VarId> = (first_candidate..bins.len()).map(|_| m.new_var()).collect();
    let z_of = |b: usize| -> VarId { z[b - first_candidate] };

    // ---- constraints -------------------------------------------------------
    // Every pod placed exactly once — emitted as `≤ 1` plus `≥ 1`
    // rather than one `=` row: the at-most-one half is what the search
    // engine detects as a branchable group (pick one bin or none), and
    // the coverage half forces the "one".
    let from = m.next_constraint_index();
    for row in &x {
        let e = LinearExpr::of(row.iter().flatten().map(|&v| (v, 1)));
        m.add_le(e.clone(), 1);
        m.add_ge(e, 1);
    }
    m.tag_constraints(from, "placement");
    // Per-bin knapsacks on every demanded dimension.
    for (b, bin) in bins.iter().enumerate() {
        let node = &bin_nodes[b];
        let (free_cpu, free_ram) = match bin {
            Bin::Existing(id) => (state.free(*id).cpu, state.free(*id).ram),
            Bin::Candidate { .. } => (node.capacity.cpu, node.capacity.ram),
        };
        let mut cpu = LinearExpr::new();
        let mut ram = LinearExpr::new();
        for (i, &pod_id) in pods.iter().enumerate() {
            if let Some(v) = x[i][b] {
                let req = state.pod(pod_id).request;
                cpu.add(v, req.cpu);
                ram.add(v, req.ram);
            }
        }
        let is_candidate = matches!(bin, Bin::Candidate { .. });
        if is_candidate {
            // A shut-off candidate offers zero capacity: Σ r·x + cap·z ≤ cap.
            cpu.add(z_of(b), free_cpu);
            ram.add(z_of(b), free_ram);
        }
        if !cpu.terms.is_empty() {
            m.add_le(cpu, free_cpu);
            m.tag_constraint(m.next_constraint_index() - 1, "capacity:cpu");
        }
        if !ram.terms.is_empty() {
            m.add_le(ram, free_ram);
            m.tag_constraint(m.next_constraint_index() - 1, "capacity:ram");
        }
        for dim in &dims {
            let cap = match bin {
                Bin::Existing(id) => state.free_extended(*id, dim),
                Bin::Candidate { .. } => node.extended_capacity(dim),
            };
            let mut e = LinearExpr::new();
            for (i, &pod_id) in pods.iter().enumerate() {
                let d: i64 = state
                    .pod(pod_id)
                    .extended
                    .iter()
                    .filter(|(k, _)| k == dim)
                    .map(|&(_, v)| v)
                    .sum();
                if d > 0 {
                    if let Some(v) = x[i][b] {
                        e.add(v, d);
                    }
                }
            }
            if e.terms.is_empty() {
                continue;
            }
            if is_candidate && cap > 0 {
                e.add(z_of(b), cap);
            }
            m.add_le(e, cap);
            m.tag_constraint(m.next_constraint_index() - 1, &format!("capacity:{dim}"));
        }
        // A shut-off candidate takes no pods at all (covers zero-request
        // pods the knapsack rows cannot exclude). Coefficient 2 on
        // purpose: `2x + 2z ≤ 2` is the same exclusion as `x + z ≤ 1`,
        // but the search engine classifies unit-coefficient/rhs-1 rows
        // as at-most-one groups and drops them from its symmetry
        // signatures — which would blind node symmetry-skipping to the
        // x↔z coupling (the same idiom as the packing model's
        // PodAntiAffinity rows).
        if is_candidate {
            let from = m.next_constraint_index();
            for row in &x {
                if let Some(v) = row[b] {
                    m.add_le(LinearExpr::of([(v, 2), (z_of(b), 2)]), 2);
                }
            }
            m.tag_constraints(from, "provisioning-coupling");
        }
    }
    // Pairwise anti-affinity among the pending pods on shared bins
    // (coefficient 2 — the same symmetry-safety idiom as the packing
    // model's PodAntiAffinity module).
    let from = m.next_constraint_index();
    for i in 0..pods.len() {
        for k in i + 1..pods.len() {
            let (a, b) = (state.pod(pods[i]), state.pod(pods[k]));
            if !(a.anti_affine_with(b) || b.anti_affine_with(a)) {
                continue;
            }
            for bin in 0..bins.len() {
                if let (Some(vi), Some(vk)) = (x[i][bin], x[k][bin]) {
                    m.add_le(LinearExpr::of([(vi, 2), (vk, 2)]), 2);
                }
            }
        }
    }
    m.tag_constraints(from, "anti-affinity");
    // Per-pool prefix symmetry: provisioned candidates are ordinals
    // 0..count (z non-decreasing in the ordinal): z_k − z_{k+1} ≤ 0.
    let from = m.next_constraint_index();
    for p in 0..pools.len() {
        for k in 0..per_pool_candidates.saturating_sub(1) {
            let a = z[p * per_pool_candidates + k];
            let b = z[p * per_pool_candidates + k + 1];
            m.add_le(LinearExpr::of([(a, 1), (b, -1)]), 0);
        }
    }
    m.tag_constraints(from, "provisioning-coupling");
    // Warm hint: provision nothing (steers the search toward cheap
    // fleets first; never assumed valid).
    for &zv in &z {
        m.hint(zv, true);
    }

    // ---- phase A: minimise cost (maximise unspent cost) --------------------
    let cost_of = |b: usize| -> i64 {
        match bins[b] {
            Bin::Candidate { pool, .. } => pools[pool].cost,
            Bin::Existing(_) => 0,
        }
    };
    let obj_cost = LinearExpr::of(
        (first_candidate..bins.len()).map(|b| (z_of(b), cost_of(b))),
    )
    .normalized();
    let total_cost: i64 = (first_candidate..bins.len()).map(cost_of).sum();

    let sol_a = {
        let sp = tel.span("provision-cost");
        sp.arg("bins", bins.len());
        solve_portfolio_traced(&m, &obj_cost, deadline, solver, portfolio, None, tel).solution
    };
    match sol_a.status {
        SolveStatus::Infeasible => return ProvisionOutcome::Infeasible,
        SolveStatus::Unknown => return ProvisionOutcome::Unknown,
        _ => {}
    }
    let cost_status = sol_a.status;
    // Unspent-cost upper bound ⇒ provisioned-cost lower bound.
    let cost_bound = total_cost - sol_a.bound.min(total_cost);

    // ---- phase B: minimise node count at locked cost -----------------------
    m.add_constraint(
        obj_cost.clone(),
        if cost_status == SolveStatus::Optimal {
            CmpOp::Eq
        } else {
            CmpOp::Ge
        },
        sol_a.objective,
    );
    let obj_count =
        LinearExpr::of((first_candidate..bins.len()).map(|b| (z_of(b), 1))).normalized();
    let sol_b = {
        let _sp = tel.span("provision-count");
        solve_portfolio_traced(&m, &obj_count, deadline, solver, portfolio, None, tel).solution
    };
    let (count_status, values) = if sol_b.status.has_solution() {
        (sol_b.status, sol_b.values)
    } else {
        // Phase B ran out of window: keep phase A's (cost-certified)
        // fleet and report the count uncertified.
        (SolveStatus::Unknown, sol_a.values)
    };
    debug_assert!(m.feasible(&values) || !sol_b.status.has_solution());

    // ---- extract the plan --------------------------------------------------
    let mut per_pool: Vec<(String, usize)> =
        pools.iter().map(|p| (p.name.clone(), 0)).collect();
    let mut cost = 0i64;
    for b in first_candidate..bins.len() {
        if !values[z_of(b).idx()] {
            if let Bin::Candidate { pool, .. } = bins[b] {
                per_pool[pool].1 += 1;
                cost += pools[pool].cost;
            }
        }
    }
    let node_count: usize = per_pool.iter().map(|(_, c)| *c).sum();
    let mut placements = Vec::with_capacity(pods.len());
    for (i, &pod_id) in pods.iter().enumerate() {
        for (b, v) in x[i].iter().enumerate() {
            let Some(v) = v else { continue };
            if values[v.idx()] {
                let target = match bins[b] {
                    Bin::Existing(id) => ProvisionTarget::Existing(id),
                    Bin::Candidate { pool, ordinal } => {
                        debug_assert!(ordinal < per_pool[pool].1, "prefix symmetry");
                        ProvisionTarget::New { pool, ordinal }
                    }
                };
                placements.push((pod_id, target));
                break;
            }
        }
    }
    debug_assert_eq!(placements.len(), pods.len(), "every pod placed");

    ProvisionOutcome::Plan(ProvisionPlan {
        per_pool,
        node_count,
        cost,
        cost_bound,
        cost_status,
        count_status,
        placements,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, Priority, Taint, Toleration};

    fn solve(
        state: &ClusterState,
        pods: &[PodId],
        pools: &[NodePool],
        reference: Resources,
    ) -> ProvisionOutcome {
        plan_provisioning(
            state,
            pods,
            pools,
            reference,
            4,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &PortfolioConfig::default(),
            &ModuleRegistry::standard(),
            &Telemetry::off(),
        )
    }

    fn plan(outcome: ProvisionOutcome) -> ProvisionPlan {
        match outcome {
            ProvisionOutcome::Plan(p) => p,
            other => panic!("expected a plan, got {other:?}"),
        }
    }

    /// A full single-node cluster with two pending half-size pods: one
    /// `small` node (half the reference) holds exactly one pod, so the
    /// certified minimum is either 2×small (cost 10) or 1×large
    /// (cost 16) — cost picks the smalls.
    #[test]
    fn min_cost_prefers_cheapest_sufficient_fleet() {
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "resident", Resources::new(1000, 1000), Priority(0)),
            Pod::new(1, "p1", Resources::new(400, 400), Priority(0)),
            Pod::new(2, "p2", Resources::new(400, 400), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();

        let p = plan(solve(
            &st,
            &[PodId(1), PodId(2)],
            &NodePool::standard_mix(),
            Resources::new(1000, 1000),
        ));
        assert!(p.certified(), "tiny model must certify both phases");
        assert_eq!(p.cost, 10, "2x small beats 1x large on cost");
        assert_eq!(p.cost_bound, 10);
        assert_eq!(p.node_count, 2);
        assert_eq!(p.per_pool, vec![("small".to_string(), 2), ("large".to_string(), 0)]);
        assert_eq!(p.placements.len(), 2);
        assert_eq!(p.mix_label(), "small x2");
    }

    /// One pod too big for `small` forces the `large` pool even though
    /// it costs more.
    #[test]
    fn packing_forces_the_larger_pool_when_needed() {
        let st = ClusterState::new(
            identical_nodes(0, Resources::ZERO),
            vec![Pod::new(0, "big", Resources::new(900, 900), Priority(0))],
        );
        let p = plan(solve(
            &st,
            &[PodId(0)],
            &NodePool::standard_mix(),
            Resources::new(1000, 1000),
        ));
        assert!(p.certified());
        assert_eq!(p.per_pool, vec![("small".to_string(), 0), ("large".to_string(), 1)]);
        assert_eq!(p.cost, 16);
    }

    /// Spare capacity on an existing node is free: no provisioning at
    /// all when the pending pod fits an existing residual.
    #[test]
    fn existing_spare_capacity_costs_nothing() {
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![Pod::new(0, "p", Resources::new(300, 300), Priority(0))];
        let st = ClusterState::new(nodes, pods);
        let p = plan(solve(
            &st,
            &[PodId(0)],
            &NodePool::standard_mix(),
            Resources::new(1000, 1000),
        ));
        assert!(p.certified());
        assert_eq!(p.cost, 0);
        assert_eq!(p.node_count, 0);
        assert_eq!(p.placements, vec![(PodId(0), ProvisionTarget::Existing(NodeId(0)))]);
    }

    /// GPU pods are only admissible on the gpu pool; the plan pays for it.
    #[test]
    fn extended_demand_selects_the_gpu_pool() {
        let st = ClusterState::new(
            identical_nodes(0, Resources::ZERO),
            vec![
                Pod::new(0, "g1", Resources::new(100, 100), Priority(0)).with_extended("gpu", 2),
                Pod::new(1, "g2", Resources::new(100, 100), Priority(0)).with_extended("gpu", 2),
            ],
        );
        let pools = vec![NodePool::small(), NodePool::gpu()];
        let p = plan(solve(&st, &[PodId(0), PodId(1)], &pools, Resources::new(1000, 1000)));
        assert!(p.certified());
        // both pods share one 4-gpu node — min cost AND min count
        assert_eq!(p.per_pool, vec![("small".to_string(), 0), ("gpu".to_string(), 1)]);
        assert_eq!(p.cost, 30);
    }

    /// A pod no pool can host is proven infeasible before the solver runs.
    #[test]
    fn impossible_pod_is_proven_infeasible() {
        let st = ClusterState::new(
            identical_nodes(0, Resources::ZERO),
            vec![Pod::new(0, "xxl", Resources::new(99_999, 99_999), Priority(0))],
        );
        assert!(matches!(
            solve(&st, &[PodId(0)], &NodePool::standard_mix(), Resources::new(1000, 1000)),
            ProvisionOutcome::Infeasible
        ));
    }

    /// Tainted pools only admit tolerating pods — the constraint-module
    /// vocabulary applies to candidates exactly as to real nodes.
    #[test]
    fn tainted_pool_requires_toleration() {
        let tainted = NodePool::new("batch", 1000, 3)
            .with_taint(Taint::no_schedule("dedicated", "batch"));
        let st = ClusterState::new(
            identical_nodes(0, Resources::ZERO),
            vec![
                Pod::new(0, "plain", Resources::new(100, 100), Priority(0)),
                Pod::new(1, "tol", Resources::new(100, 100), Priority(0))
                    .with_toleration(Toleration::equal("dedicated", "batch")),
            ],
        );
        // Only the tainted pool on the menu: the plain pod is infeasible.
        assert!(matches!(
            solve(&st, &[PodId(0)], std::slice::from_ref(&tainted), Resources::new(1000, 1000)),
            ProvisionOutcome::Infeasible
        ));
        // The tolerating pod provisions a batch node.
        let p = plan(solve(
            &st,
            &[PodId(1)],
            std::slice::from_ref(&tainted),
            Resources::new(1000, 1000),
        ));
        assert_eq!(p.node_count, 1);
        assert_eq!(p.cost, 3);
    }

    /// Anti-affine pending pods never share a provisioned node.
    #[test]
    fn anti_affinity_splits_pods_across_candidates() {
        let st = ClusterState::new(
            identical_nodes(0, Resources::ZERO),
            vec![
                Pod::new(0, "a", Resources::new(100, 100), Priority(0))
                    .with_label("app", "x")
                    .with_anti_affinity("app", "x"),
                Pod::new(1, "b", Resources::new(100, 100), Priority(0)).with_label("app", "x"),
            ],
        );
        let pools = vec![NodePool::small()];
        let p = plan(solve(&st, &[PodId(0), PodId(1)], &pools, Resources::new(1000, 1000)));
        assert!(p.certified());
        assert_eq!(p.node_count, 2, "exclusion forces two nodes");
        let targets: Vec<_> = p.placements.iter().map(|&(_, t)| t).collect();
        assert_ne!(targets[0], targets[1]);
    }

    /// Applying a plan joins the nodes deterministically and binds every
    /// placement — all-or-nothing.
    #[test]
    fn apply_joins_and_binds() {
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "resident", Resources::new(1000, 1000), Priority(0)),
            Pod::new(1, "p1", Resources::new(400, 400), Priority(0)),
            Pod::new(2, "p2", Resources::new(400, 400), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        let pools = NodePool::standard_mix();
        let reference = Resources::new(1000, 1000);
        let p = plan(solve(&st, &[PodId(1), PodId(2)], &pools, reference));
        let joined = p.apply(&mut st, &pools, reference).unwrap();
        assert_eq!(joined.len(), 2);
        assert_eq!(st.pending_pods(), Vec::<PodId>::new());
        assert!(st.node(joined[0]).name.starts_with("node-"));
        st.check_invariants().unwrap();
    }

    /// The plan is identical at 1 and 8 portfolio threads (the PR 3
    /// determinism contract carried into provisioning).
    #[test]
    fn plans_are_thread_independent() {
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "resident", Resources::new(900, 900), Priority(0)),
            Pod::new(1, "p1", Resources::new(500, 500), Priority(0)),
            Pod::new(2, "p2", Resources::new(500, 500), Priority(0)),
            Pod::new(3, "p3", Resources::new(200, 200), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        let pending = [PodId(1), PodId(2), PodId(3)];
        let reference = Resources::new(1000, 1000);
        let base = plan(plan_provisioning(
            &st,
            &pending,
            &NodePool::standard_mix(),
            reference,
            4,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &PortfolioConfig::with_threads(1),
            &ModuleRegistry::standard(),
            &Telemetry::off(),
        ));
        let threaded = plan(plan_provisioning(
            &st,
            &pending,
            &NodePool::standard_mix(),
            reference,
            4,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &PortfolioConfig::with_threads(8),
            &ModuleRegistry::standard(),
            &Telemetry::off(),
        ));
        assert_eq!(base.per_pool, threaded.per_pool);
        assert_eq!(base.cost, threaded.cost);
        assert_eq!(base.placements, threaded.placements);
        assert!(base.certified() && threaded.certified());
    }

    #[test]
    fn zero_max_per_pool_disables_provisioning() {
        // "Consolidate only": no candidates are offered, so a pod that
        // needs a new node is proven Infeasible within the limits —
        // never silently floored to one candidate.
        let st = ClusterState::new(
            identical_nodes(0, Resources::ZERO),
            vec![Pod::new(0, "p", Resources::new(100, 100), Priority(0))],
        );
        let out = plan_provisioning(
            &st,
            &[PodId(0)],
            &NodePool::standard_mix(),
            Resources::new(1000, 1000),
            0,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &PortfolioConfig::default(),
            &ModuleRegistry::standard(),
            &Telemetry::off(),
        );
        assert!(matches!(out, ProvisionOutcome::Infeasible));
        // ... while a pod that fits existing spare capacity still plans.
        let roomy = ClusterState::new(
            identical_nodes(1, Resources::new(1000, 1000)),
            vec![Pod::new(0, "p", Resources::new(100, 100), Priority(0))],
        );
        let p = plan(plan_provisioning(
            &roomy,
            &[PodId(0)],
            &NodePool::standard_mix(),
            Resources::new(1000, 1000),
            0,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &PortfolioConfig::default(),
            &ModuleRegistry::standard(),
            &Telemetry::off(),
        ));
        assert_eq!(p.node_count, 0);
        assert!(p.certified());
    }

    #[test]
    fn empty_pod_set_is_a_trivial_certified_plan() {
        let st = ClusterState::new(identical_nodes(1, Resources::new(10, 10)), vec![]);
        let p = plan(solve(&st, &[], &NodePool::standard_mix(), Resources::new(10, 10)));
        assert!(p.certified());
        assert_eq!(p.node_count, 0);
        assert_eq!(p.cost, 0);
    }
}
