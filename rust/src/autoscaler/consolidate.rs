//! Consolidation scale-down: prove a node drainable, then remove it.
//!
//! The mirror image of certificate-guided scale-up. Where the
//! provisioning model answers "what is the cheapest fleet that makes the
//! pending set placeable", consolidation answers "which nodes can leave
//! without making anything unplaceable" — and insists on a *proof*
//! before acting, reusing the defrag-sweep machinery (trial-clone
//! re-pack under an eviction budget) and the incremental
//! [`SolveSession`] warm-starts across candidates:
//!
//! 1. Candidates are Ready nodes, emptiest first (fewest resident pods,
//!    then id) — the cheapest drains are tried first.
//! 2. For each candidate, a log-detached trial clone drains it and
//!    re-packs the cluster with Algorithm 1. The candidate is *provably
//!    removable* iff the re-pack is fully certified (`proved_optimal`)
//!    and its placement vector loses nothing in any priority tier.
//! 3. The disruption price — drained residents plus every re-pack move —
//!    must fit the eviction budget, exactly like a sweep plan.
//! 4. Only then does the live state drain, execute the move plan
//!    (evictions attributed to [`EvictCause::Sweep`]: consolidation
//!    moves are elective, like defragmentation), and remove the node —
//!    emitting the `NodeDrained` / `NodeRemoved` lifecycle events churn
//!    traces replay.
//!
//! Determinism: candidate order, certificates, and budgets are all pure
//! functions of the state and config, so consolidation decisions inherit
//! the solver's thread-independence — identical at any worker count
//! whenever the solves complete in-window.

use crate::cluster::{ClusterState, EvictCause, NodeId};
use crate::optimizer::algorithm::{optimize_traced, OptimizerConfig};
use crate::optimizer::plan::MovePlan;
use crate::optimizer::session::SolveSession;
use crate::telemetry::Telemetry;

use super::policy::AutoscaleConfig;

/// What one consolidation pass did.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConsolidationPass {
    /// Candidates examined (solves attempted + empty-node fast paths).
    pub considered: usize,
    /// Nodes drained and removed, in removal order.
    pub removed: Vec<NodeId>,
    /// Re-pack moves executed (pods whose node changed beyond the drain).
    pub moves: usize,
    /// Resident pods drained off removed nodes.
    pub drained_pods: usize,
    /// Candidates whose certified drain plan exceeded the budget.
    pub vetoed_budget: usize,
    /// Candidates with no certified lossless re-pack (kept).
    pub blocked: usize,
}

impl ConsolidationPass {
    pub fn removed_any(&self) -> bool {
        !self.removed.is_empty()
    }
}

/// `a` serves at least as many pods as `b` in every tier (elementwise ≥).
fn no_tier_loses(a: &[usize], b: &[usize]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x >= y)
}

/// Run one consolidation pass over the live cluster. `optimizer` is the
/// re-pack configuration (typically the sweep's); `session` carries
/// certificates and warm starts across candidates and across passes.
pub fn run_consolidation(
    state: &mut ClusterState,
    p_max: u32,
    cfg: &AutoscaleConfig,
    optimizer: &OptimizerConfig,
    mut session: Option<&mut SolveSession>,
    tel: &Telemetry,
) -> ConsolidationPass {
    let sp = tel.span("consolidate");
    tel.add("autoscaler_consolidation_passes_total", "", 1);
    let mut pass = ConsolidationPass::default();
    let mut rejected: Vec<NodeId> = Vec::new();

    while pass.removed.len() < cfg.max_removals {
        // Pending pods mean the spare capacity is already spoken for —
        // scaling down now would fight the very scale-up path.
        if !state.pending_pods().is_empty() {
            break;
        }
        let ready: Vec<NodeId> = state
            .nodes()
            .iter()
            .filter(|n| state.node_ready(n.id))
            .map(|n| n.id)
            .collect();
        if ready.len() <= cfg.min_nodes {
            break;
        }
        // Emptiest first: fewest residents, then id — the cheapest drain
        // is the likeliest to certify.
        let candidate = ready
            .iter()
            .copied()
            .filter(|n| !rejected.contains(n))
            .min_by_key(|&n| (state.pods_on(n).len(), n));
        let Some(candidate) = candidate else { break };
        pass.considered += 1;

        let victims = state.pods_on(candidate);
        if victims.len() > cfg.consolidation_budget {
            pass.vetoed_budget += 1;
            rejected.push(candidate);
            continue;
        }
        if victims.is_empty() {
            // Empty node: trivially removable, no solve needed.
            state.drain(candidate); // cordon (0 evictions) + NodeDrained
            state
                .remove_node(candidate)
                .expect("drained node is empty");
            pass.removed.push(candidate);
            continue;
        }

        // Trial: drain the candidate on a log-detached clone and re-pack.
        // On success the SAME clone becomes the committed state — one
        // clone and one drain per removal, not two.
        let before = state.placed_per_priority(p_max);
        let log = std::mem::take(&mut state.events);
        let mut trial = state.clone();
        state.events = log; // the live log goes straight back
        trial.drain(candidate);
        let result = {
            let sp = tel.span("consolidate-trial");
            sp.arg("node", candidate.0);
            sp.arg("residents", victims.len());
            match session.as_deref_mut() {
                Some(sess) => sess.solve_traced(&trial, p_max, optimizer, tel),
                None => optimize_traced(&trial, p_max, optimizer, None, tel),
            }
        };
        let Some(res) = result else {
            pass.blocked += 1;
            rejected.push(candidate);
            continue;
        };
        if !res.proved_optimal || !no_tier_loses(&res.placed_per_priority, &before) {
            // No *certified* lossless re-pack without this node.
            pass.blocked += 1;
            rejected.push(candidate);
            continue;
        }
        let plan = MovePlan::build(&trial, &res.target);
        let disruption = victims.len() + plan.disruptions();
        if disruption > cfg.consolidation_budget {
            pass.vetoed_budget += 1;
            rejected.push(candidate);
            continue;
        }

        // Commit, all-or-nothing (sweep idiom): finish the plan on the
        // already-drained trial and adopt it; a failure discards the
        // trial and leaves the live state untouched.
        let committed = (|| -> Result<(), String> {
            plan.execute_as(&mut trial, EvictCause::Sweep)?;
            trial.remove_node(candidate).map_err(|e| e.to_string())
        })();
        match committed {
            Ok(()) => {
                let mut log = std::mem::take(&mut state.events);
                *state = trial;
                log.append(&mut state.events); // the trial's fresh events
                state.events = log;
                pass.removed.push(candidate);
                pass.moves += plan.disruptions();
                pass.drained_pods += victims.len();
            }
            Err(_) => {
                // Unreachable with the built-in module/filter sets (the
                // certified target satisfies bind's vocabulary); kept
                // graceful for custom modules, like the sweep.
                pass.blocked += 1;
                rejected.push(candidate);
            }
        }
    }
    sp.arg("considered", pass.considered);
    sp.arg("removed", pass.removed.len());
    if tel.enabled() {
        tel.add("autoscaler_nodes_removed_total", "", pass.removed.len() as u64);
        tel.add("autoscaler_consolidation_moves_total", "", pass.moves as u64);
    }
    pass
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Pod, PodId, Priority, Resources};

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            max_removals: 8,
            ..AutoscaleConfig::default()
        }
    }

    /// Three nodes, two small pods spread over two of them: the pass
    /// consolidates onto one node and removes the other two.
    #[test]
    fn consolidates_spread_pods_and_removes_nodes() {
        let nodes = identical_nodes(3, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(300, 300), Priority(0)),
            Pod::new(1, "b", Resources::new(300, 300), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();

        let pass = run_consolidation(
            &mut st,
            0,
            &cfg(),
            &OptimizerConfig::with_timeout(5.0),
            None,
            &Telemetry::off(),
        );
        assert_eq!(pass.removed.len(), 2, "two of three nodes drain away");
        assert_eq!(st.placed_per_priority(0), vec![2], "nothing lost");
        assert_eq!(
            st.nodes()
                .iter()
                .filter(|n| st.node_ready(n.id))
                .count(),
            1
        );
        assert!(pass.drained_pods >= 1, "at least one pod moved off a node");
        // lifecycle events emitted for the churn trace
        assert!(st.events.all().iter().any(|e| matches!(
            e,
            crate::cluster::Event::NodeRemoved { .. }
        )));
        st.check_invariants().unwrap();
    }

    /// A full cluster has no removable node: every candidate is blocked
    /// by the lossless-re-pack certificate.
    #[test]
    fn full_cluster_keeps_every_node() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(900, 900), Priority(0)),
            Pod::new(1, "b", Resources::new(900, 900), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        let pass = run_consolidation(
            &mut st,
            0,
            &cfg(),
            &OptimizerConfig::with_timeout(5.0),
            None,
            &Telemetry::off(),
        );
        assert!(pass.removed.is_empty());
        assert!(pass.blocked >= 1);
        assert_eq!(st.placed_per_priority(0), vec![2]);
    }

    /// The eviction budget vetoes a certified but too-disruptive drain.
    #[test]
    fn budget_vetoes_disruptive_drains() {
        let nodes = identical_nodes(2, Resources::new(1000, 1000));
        let pods = vec![
            Pod::new(0, "a", Resources::new(300, 300), Priority(0)),
            Pod::new(1, "b", Resources::new(300, 300), Priority(0)),
        ];
        let mut st = ClusterState::new(nodes, pods);
        st.bind(PodId(0), NodeId(0)).unwrap();
        st.bind(PodId(1), NodeId(1)).unwrap();
        let tight = AutoscaleConfig {
            consolidation_budget: 0,
            max_removals: 8,
            ..AutoscaleConfig::default()
        };
        let pass = run_consolidation(
            &mut st,
            0,
            &tight,
            &OptimizerConfig::with_timeout(5.0),
            None,
            &Telemetry::off(),
        );
        assert!(pass.removed.is_empty(), "budget 0 vetoes every drain");
        assert!(pass.vetoed_budget >= 1);
        assert_eq!(st.assignment_of(PodId(0)), Some(NodeId(0)), "untouched");
    }

    /// Pending pods freeze consolidation outright.
    #[test]
    fn pending_pods_block_scale_down() {
        let nodes = identical_nodes(3, Resources::new(1000, 1000));
        let pods = vec![Pod::new(0, "pending", Resources::new(100, 100), Priority(0))];
        let mut st = ClusterState::new(nodes, pods);
        let pass = run_consolidation(
            &mut st,
            0,
            &cfg(),
            &OptimizerConfig::with_timeout(2.0),
            None,
            &Telemetry::off(),
        );
        assert_eq!(pass, ConsolidationPass::default());
    }

    /// `min_nodes` floors the fleet even when everything is empty.
    #[test]
    fn min_nodes_floor_is_respected() {
        let nodes = identical_nodes(4, Resources::new(1000, 1000));
        let mut st = ClusterState::new(nodes, vec![]);
        let floor = AutoscaleConfig {
            min_nodes: 2,
            max_removals: 8,
            ..AutoscaleConfig::default()
        };
        let pass = run_consolidation(
            &mut st,
            0,
            &floor,
            &OptimizerConfig::with_timeout(2.0),
            None,
            &Telemetry::off(),
        );
        assert_eq!(pass.removed.len(), 2, "stops at the floor");
        assert_eq!(
            st.nodes().iter().filter(|n| st.node_ready(n.id)).count(),
            2
        );
    }

    /// Session-backed passes decide exactly like cold ones.
    #[test]
    fn session_and_cold_passes_agree() {
        let build = || {
            let nodes = identical_nodes(3, Resources::new(1000, 1000));
            let pods = vec![
                Pod::new(0, "a", Resources::new(300, 300), Priority(0)),
                Pod::new(1, "b", Resources::new(300, 300), Priority(0)),
            ];
            let mut st = ClusterState::new(nodes, pods);
            st.bind(PodId(0), NodeId(0)).unwrap();
            st.bind(PodId(1), NodeId(1)).unwrap();
            st
        };
        let opt = OptimizerConfig::with_timeout(5.0);
        let mut cold_st = build();
        let cold = run_consolidation(&mut cold_st, 0, &cfg(), &opt, None, &Telemetry::off());
        let mut warm_st = build();
        let mut session = SolveSession::new();
        let warm = run_consolidation(&mut warm_st, 0, &cfg(), &opt, Some(&mut session), &Telemetry::off());
        assert_eq!(cold.removed, warm.removed);
        assert_eq!(cold.moves, warm.moves);
        assert_eq!(cold_st.assignment(), warm_st.assignment());
        assert!(session.stats.solves > 0, "the session actually solved");
    }
}
