//! CP-driven cluster autoscaler: certificate-guided scale-up and
//! consolidation scale-down.
//!
//! Every other subsystem in this repo changes the *pod* side of the
//! instance — where the workload lands on a fixed fleet. This one closes
//! the loop on the *node* side, turning solver certificates into
//! cluster-size decisions:
//!
//! * **Scale-up** ([`provision`]): when Algorithm 1 proves a priority
//!   tier's placement count maximal with pods still pending
//!   ([`certified_unplaceable`]), those pods are provably stuck — "the
//!   cluster is full" is no longer a guess. A second CP model then
//!   computes *the cheapest set of nodes that makes it not full*:
//!   candidate nodes drawn from configurable [`NodePool`]s
//!   (heterogeneous capacities, extended resources, taints, costs),
//!   minimising cost then node count, each phase with its own
//!   optimality certificate.
//! * **Scale-down** ([`consolidate`]): the defrag-sweep machinery run in
//!   reverse — a trial-clone drain plus a fully certified lossless
//!   re-pack *proves* a node removable within the eviction budget before
//!   the live cluster drains and removes it.
//! * **Policy** ([`policy`]): the [`AutoscaleConfig`] opt-in knob
//!   (`OptimizerConfig.autoscale`, churn's `--autoscale`) and the
//!   certificate-extraction trigger.
//! * **Pools** ([`pools`]): the provisioning menu, also reused by the
//!   workload generator's heterogeneous-fleet scenario family
//!   (`--node-pools small,large,gpu`).
//! * **Reporting** ([`report`]): per-decision records, run-level
//!   aggregates, and the byte-stable log lines the churn determinism
//!   digests cover.
//!
//! Scale decisions are pure functions of the cluster state and the
//! config whenever the underlying solves complete in-window, so they
//! inherit the portfolio's thread-independence and the session layer's
//! replay guarantees — the properties `rust/tests/autoscaler.rs` pins.

pub mod consolidate;
pub mod policy;
pub mod pools;
pub mod provision;
pub mod report;

pub use consolidate::{run_consolidation, ConsolidationPass};
pub use policy::{certified_unplaceable, AutoscaleConfig};
pub use pools::NodePool;
pub use provision::{plan_provisioning, ProvisionOutcome, ProvisionPlan, ProvisionTarget};
pub use report::{consolidation_log_line, AutoscaleStats, ScaleUpReport};
