//! Autoscaling policy: when to scale, from which menu, within what
//! limits.
//!
//! [`AutoscaleConfig`] is the single opt-in knob drivers carry (an
//! `Option` on [`OptimizerConfig`] and `ChurnConfig`); the free
//! functions here translate solver evidence into decisions:
//! [`certified_unplaceable`] extracts the pods whose pending state the
//! fallback *proved* — the only trigger the scale-up path ever acts on.
//! Heuristic pending pods (deadline-truncated tiers) never trigger
//! provisioning: buying nodes on an unproven "the cluster is full" is
//! how real autoscalers over-provision.
//!
//! [`OptimizerConfig`]: crate::optimizer::algorithm::OptimizerConfig

use std::time::Duration;

use crate::cluster::{ClusterState, NodeStatus, PodId, Resources};
use crate::optimizer::algorithm::OptimizeResult;
use crate::solver::SolveStatus;
use crate::util::fingerprint::Fnv64;

use super::pools::NodePool;

/// Autoscaler knobs (scale-up and consolidation).
#[derive(Clone, Debug, PartialEq)]
pub struct AutoscaleConfig {
    /// The provisioning menu (pool order is plan order).
    pub pools: Vec<NodePool>,
    /// Candidate nodes per pool offered to one provisioning solve
    /// (further clamped to the pending-pod count). `0` disables
    /// provisioning outright: scale-up solves then cover existing spare
    /// capacity only and report Infeasible-within-limits for anything
    /// that needs a new node.
    pub max_per_pool: usize,
    /// Wall-clock budget of one provisioning solve (both phases).
    pub provision_timeout: Duration,
    /// Reference capacity the pool scales apply to. `None` derives the
    /// component-wise maximum capacity over non-removed nodes — "a
    /// standard node of this cluster". Drivers pin the derivation so
    /// autoscaled nodes can never inflate later scale-ups (a joined
    /// `large` raising the max would make every subsequent candidate
    /// 1.5× bigger at the same cost, geometrically): the churn runner
    /// resolves `None` to the trace's `reference_capacity` up front, and
    /// [`OptimizingScheduler`] snapshots the first derivation for its
    /// lifetime.
    ///
    /// [`OptimizingScheduler`]: crate::optimizer::plugin::OptimizingScheduler
    pub reference: Option<Resources>,
    /// Run the consolidation (scale-down) pass at sweep ticks.
    pub consolidate: bool,
    /// Disruption budget of one node drain: drained residents plus
    /// re-pack moves (the sweep's eviction-budget semantics).
    pub consolidation_budget: usize,
    /// Maximum nodes removed per consolidation pass.
    pub max_removals: usize,
    /// Never consolidate below this many Ready nodes.
    pub min_nodes: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            pools: NodePool::standard_mix(),
            max_per_pool: 8,
            provision_timeout: Duration::from_secs(2),
            reference: None,
            consolidate: true,
            consolidation_budget: 8,
            max_removals: 1,
            min_nodes: 1,
        }
    }
}

impl AutoscaleConfig {
    /// Replace the provisioning menu (builder style).
    pub fn with_pools(mut self, pools: Vec<NodePool>) -> Self {
        self.pools = pools;
        self
    }

    /// The capacity pool scales apply to: the configured reference, or
    /// the component-wise max over non-removed nodes (zero on an empty
    /// cluster — every pool then scales from nothing, so configure an
    /// explicit reference for from-scratch provisioning).
    pub fn reference_capacity(&self, state: &ClusterState) -> Resources {
        if let Some(r) = self.reference {
            return r;
        }
        state
            .nodes()
            .iter()
            .filter(|n| state.node_status(n.id) != NodeStatus::Removed)
            .fold(Resources::ZERO, |acc, n| acc.max(&n.capacity))
    }

    /// Cache identity of every decision-relevant knob — folded into the
    /// optimiser-config fingerprint so incremental sessions invalidate
    /// when the autoscaling policy changes.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_usize(self.pools.len());
        for p in &self.pools {
            h.write_u64(p.fingerprint());
        }
        h.write_usize(self.max_per_pool)
            .write_u64(self.provision_timeout.as_nanos() as u64);
        match self.reference {
            Some(r) => h.tag(1).write_i64(r.cpu).write_i64(r.ram),
            None => h.tag(0),
        };
        h.write_bool(self.consolidate)
            .write_usize(self.consolidation_budget)
            .write_usize(self.max_removals)
            .write_usize(self.min_nodes);
        h.finish()
    }
}

/// The pods an optimisation run *proved* unplaceable: still pending,
/// left unplaced by the target, and belonging to a tier whose phase-1
/// solve closed its bound (`Optimal`) — so the tier's placement count
/// is provably maximal and *some* pod set of this size must stay
/// pending under any re-pack. This is the scale-up trigger; pods of
/// anytime (deadline-truncated) tiers are deliberately excluded.
///
/// Note the certificate's shape: the proof is about the *count*; which
/// pods make up the leftover set is the packer's deterministic choice
/// among equal-count packings. The provisioning plan downstream is
/// min-cost *for that choice* — choosing a different equal-count
/// leftover (e.g. stranding a small pod instead of a big one) could
/// admit a cheaper fleet, which only a joint re-pack-and-provision
/// model can exploit (ROADMAP follow-on).
///
/// Topology-spread pods are excluded too, even when certified stuck:
/// the provisioning model does not encode max-skew (the skew couples
/// pending pods with their already-placed owner-group mates across the
/// whole fleet), and `ClusterState::bind` deliberately doesn't enforce
/// spread either — so provisioning such a pod could persist a placement
/// the packing model itself forbids. Spread-aware provisioning is a
/// ROADMAP follow-on; until then those pods simply stay pending.
pub fn certified_unplaceable(state: &ClusterState, res: &OptimizeResult) -> Vec<PodId> {
    res.target
        .iter()
        .enumerate()
        .filter_map(|(i, target)| {
            if target.is_some() {
                return None;
            }
            let pod = &state.pods()[i];
            if state.is_retired(pod.id)
                || state.assignment_of(pod.id).is_some()
                || pod.spread_max_skew.is_some()
            {
                return None;
            }
            let tier = res.tiers.iter().find(|t| t.priority == pod.priority.0)?;
            (tier.phase1_status == SolveStatus::Optimal).then_some(pod.id)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, ClusterState, Node, NodeId, Pod, Priority};
    use crate::optimizer::algorithm::{optimize, OptimizerConfig};

    #[test]
    fn reference_capacity_is_max_over_live_nodes() {
        let mut nodes = identical_nodes(2, Resources::new(1000, 2000));
        nodes[1] = Node::new(1, "node-001", Resources::new(3000, 500));
        let mut st = ClusterState::new(nodes, vec![]);
        let cfg = AutoscaleConfig::default();
        assert_eq!(cfg.reference_capacity(&st), Resources::new(3000, 2000));
        // removed nodes drop out of the derivation
        st.drain(NodeId(1));
        st.remove_node(NodeId(1)).unwrap();
        assert_eq!(cfg.reference_capacity(&st), Resources::new(1000, 2000));
        // explicit reference wins
        let pinned = AutoscaleConfig {
            reference: Some(Resources::new(10, 10)),
            ..AutoscaleConfig::default()
        };
        assert_eq!(pinned.reference_capacity(&st), Resources::new(10, 10));
    }

    #[test]
    fn fingerprint_tracks_pools_and_knobs() {
        let base = AutoscaleConfig::default();
        assert_eq!(base.fingerprint(), AutoscaleConfig::default().fingerprint());
        let gpu = AutoscaleConfig::default()
            .with_pools(vec![NodePool::small(), NodePool::gpu()]);
        assert_ne!(base.fingerprint(), gpu.fingerprint());
        let tighter = AutoscaleConfig {
            consolidation_budget: 1,
            ..AutoscaleConfig::default()
        };
        assert_ne!(base.fingerprint(), tighter.fingerprint());
    }

    #[test]
    fn certified_unplaceable_requires_a_closed_bound() {
        // One full node, one oversized pending pod: the tier certifies
        // (tiny model, generous window) and the pod is proven stuck.
        let nodes = identical_nodes(1, Resources::new(100, 100));
        let pods = vec![Pod::new(0, "xl", Resources::new(1000, 1000), Priority(0))];
        let st = ClusterState::new(nodes, pods);
        let res = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0)).unwrap();
        assert!(res.proved_optimal);
        assert_eq!(certified_unplaceable(&st, &res), vec![PodId(0)]);
    }

    #[test]
    fn spread_constrained_pods_never_trigger_scale_up() {
        // Certified stuck, but carrying a max-skew: excluded until the
        // provisioning model learns to encode spread (see the fn docs).
        let nodes = identical_nodes(1, Resources::new(100, 100));
        let pods = vec![Pod::new(0, "xl", Resources::new(1000, 1000), Priority(0))
            .with_owner(7)
            .with_spread(1)];
        let st = ClusterState::new(nodes, pods);
        let res = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0)).unwrap();
        assert!(res.proved_optimal);
        assert_eq!(res.target[0], None, "the pod really is stuck");
        assert_eq!(certified_unplaceable(&st, &res), Vec::<PodId>::new());
    }

    #[test]
    fn placed_pods_are_never_reported() {
        let nodes = identical_nodes(1, Resources::new(1000, 1000));
        let pods = vec![Pod::new(0, "fits", Resources::new(100, 100), Priority(0))];
        let st = ClusterState::new(nodes, pods);
        let res = optimize(&st, 0, &OptimizerConfig::with_timeout(5.0)).unwrap();
        assert_eq!(certified_unplaceable(&st, &res), Vec::<PodId>::new());
    }
}
