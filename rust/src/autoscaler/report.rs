//! Autoscaler reporting: per-decision records and run-level aggregates.
//!
//! The scale-up path produces a [`ScaleUpReport`] per fallback pass (on
//! [`RunReport`]); the consolidation pass produces a
//! [`ConsolidationPass`]; [`AutoscaleStats`] folds both into the
//! run-level counters `ChurnResult` and the churn report surface. The
//! log-line renderers are deliberately byte-stable — they feed the churn
//! log whose FNV digest is the replay-determinism oracle.
//!
//! [`RunReport`]: crate::optimizer::plugin::RunReport
//! [`ConsolidationPass`]: super::consolidate::ConsolidationPass

use crate::solver::SolveStatus;
use crate::util::json::Json;

use super::consolidate::ConsolidationPass;

/// Render a per-pool provisioning count list — `"small x2 + gpu x1"`,
/// or `"none"` when nothing is provisioned. The one definition shared
/// by [`ProvisionPlan::mix_label`] and the scale-up log line, so the
/// plan and the byte-stable churn digest can never drift apart.
///
/// [`ProvisionPlan::mix_label`]: super::provision::ProvisionPlan::mix_label
pub fn mix_label(per_pool: &[(String, usize)]) -> String {
    let parts: Vec<String> = per_pool
        .iter()
        .filter(|(_, c)| *c > 0)
        .map(|(name, c)| format!("{name} x{c}"))
        .collect();
    if parts.is_empty() {
        "none".to_string()
    } else {
        parts.join(" + ")
    }
}

/// One scale-up decision (provisioning solve + application).
#[derive(Clone, Debug)]
pub struct ScaleUpReport {
    /// Certified-unplaceable pods handed to the provisioning solve.
    pub pending: usize,
    /// Provisioned nodes per pool, configuration order (zeros kept).
    pub per_pool: Vec<(String, usize)>,
    pub nodes_added: usize,
    /// Total cost of the provisioned fleet.
    pub cost: i64,
    /// Proven lower bound on any sufficient fleet's cost.
    pub cost_bound: i64,
    /// Phase certificates of the provisioning solve.
    pub cost_status: SolveStatus,
    pub count_status: SolveStatus,
    /// Both phases proven — the plan is certified min-cost-then-min-count.
    pub certified: bool,
    /// Proven: no fleet within the candidate limits can host the pods.
    pub proven_infeasible: bool,
    /// The plan was applied to the live cluster (joins + binds).
    pub applied: bool,
}

impl ScaleUpReport {
    /// Byte-stable log line, e.g.
    /// `scale-up +2 (small x2) cost=10 [certified] pods=2`.
    pub fn log_line(&self) -> String {
        if self.proven_infeasible {
            // "Within limits": the proof covers the offered candidate
            // model (menu × max_per_pool), not the menu in the abstract.
            return format!(
                "scale-up infeasible within pool limits ({} pending)",
                self.pending
            );
        }
        let mix = mix_label(&self.per_pool);
        format!(
            "scale-up +{} ({mix}) cost={} [{}]{} pods={}",
            self.nodes_added,
            self.cost,
            if self.certified {
                "certified"
            } else {
                "anytime"
            },
            if self.applied { "" } else { " NOT-APPLIED" },
            self.pending
        )
    }

    pub fn to_json(&self) -> Json {
        let mut pools = Json::obj();
        for (name, count) in &self.per_pool {
            pools.set(name, *count as u64);
        }
        let mut o = Json::obj();
        o.set("pending", self.pending as u64)
            .set("nodes_added", self.nodes_added as u64)
            .set("cost", self.cost)
            .set("cost_bound", self.cost_bound)
            .set("cost_status", self.cost_status.label())
            .set("count_status", self.count_status.label())
            .set("certified", self.certified)
            .set("proven_infeasible", self.proven_infeasible)
            .set("applied", self.applied)
            .set("per_pool", pools);
        o
    }
}

/// Run-level autoscaler counters (summed over every cycle of a churn
/// run; all zero with autoscaling off).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AutoscaleStats {
    /// Scale-up decisions applied (nodes joined + pods bound).
    pub scale_ups: usize,
    /// Scale-up solves that proved no fleet suffices (within the
    /// configured candidate limits).
    pub scale_up_infeasible: usize,
    /// Scale-up attempts that ended without an applied plan for any
    /// other reason (deadline-truncated Unknown, or a failed apply).
    pub scale_up_unknown: usize,
    pub nodes_added: usize,
    /// Total cost of every provisioned node.
    pub cost_added: i64,
    /// Applied scale-ups whose plan carried both optimality proofs.
    pub certified_scale_ups: usize,
    /// Consolidation passes that removed at least one node.
    pub scale_downs: usize,
    pub nodes_removed: usize,
    /// Re-pack moves executed by consolidation (beyond the drains).
    pub consolidation_moves: usize,
    /// Resident pods drained off removed nodes.
    pub drained_pods: usize,
}

impl AutoscaleStats {
    pub fn absorb_scale_up(&mut self, r: &ScaleUpReport) {
        if r.proven_infeasible {
            self.scale_up_infeasible += 1;
        } else if r.applied {
            self.scale_ups += 1;
            self.nodes_added += r.nodes_added;
            self.cost_added += r.cost;
            if r.certified {
                self.certified_scale_ups += 1;
            }
        } else {
            self.scale_up_unknown += 1;
        }
    }

    pub fn absorb_consolidation(&mut self, pass: &ConsolidationPass) {
        if pass.removed_any() {
            self.scale_downs += 1;
        }
        self.nodes_removed += pass.removed.len();
        self.consolidation_moves += pass.moves;
        self.drained_pods += pass.drained_pods;
    }

    pub fn merge(&mut self, other: &AutoscaleStats) {
        self.scale_ups += other.scale_ups;
        self.scale_up_infeasible += other.scale_up_infeasible;
        self.scale_up_unknown += other.scale_up_unknown;
        self.nodes_added += other.nodes_added;
        self.cost_added += other.cost_added;
        self.certified_scale_ups += other.certified_scale_ups;
        self.scale_downs += other.scale_downs;
        self.nodes_removed += other.nodes_removed;
        self.consolidation_moves += other.consolidation_moves;
        self.drained_pods += other.drained_pods;
    }

    pub fn any_activity(&self) -> bool {
        *self != AutoscaleStats::default()
    }

    /// Compact report cell, e.g. `+3/-1 cost=15` (`-` when idle).
    pub fn cell(&self) -> String {
        if !self.any_activity() {
            return "-".to_string();
        }
        format!(
            "+{}/-{} cost={}",
            self.nodes_added, self.nodes_removed, self.cost_added
        )
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("scale_ups", self.scale_ups as u64)
            .set("scale_up_infeasible", self.scale_up_infeasible as u64)
            .set("scale_up_unknown", self.scale_up_unknown as u64)
            .set("nodes_added", self.nodes_added as u64)
            .set("cost_added", self.cost_added)
            .set("certified_scale_ups", self.certified_scale_ups as u64)
            .set("scale_downs", self.scale_downs as u64)
            .set("nodes_removed", self.nodes_removed as u64)
            .set("consolidation_moves", self.consolidation_moves as u64)
            .set("drained_pods", self.drained_pods as u64);
        o
    }
}

/// Byte-stable consolidation log line, e.g.
/// `scale-down removed=1 (node-002) moves=2 drained=1`.
pub fn consolidation_log_line(pass: &ConsolidationPass, names: &[String]) -> String {
    if pass.removed.is_empty() {
        return format!(
            "scale-down none (considered={} blocked={} budget-veto={})",
            pass.considered, pass.blocked, pass.vetoed_budget
        );
    }
    format!(
        "scale-down removed={} ({}) moves={} drained={}",
        pass.removed.len(),
        names.join(", "),
        pass.moves,
        pass.drained_pods
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;

    fn up(applied: bool, certified: bool) -> ScaleUpReport {
        ScaleUpReport {
            pending: 2,
            per_pool: vec![("small".to_string(), 2), ("large".to_string(), 0)],
            nodes_added: 2,
            cost: 10,
            cost_bound: 10,
            cost_status: SolveStatus::Optimal,
            count_status: SolveStatus::Optimal,
            certified,
            proven_infeasible: false,
            applied,
        }
    }

    #[test]
    fn log_lines_are_stable_and_informative() {
        assert_eq!(
            up(true, true).log_line(),
            "scale-up +2 (small x2) cost=10 [certified] pods=2"
        );
        assert!(up(false, false).log_line().contains("NOT-APPLIED"));
        let infeasible = ScaleUpReport {
            proven_infeasible: true,
            ..up(false, false)
        };
        assert!(infeasible.log_line().contains("infeasible"));
    }

    #[test]
    fn stats_absorb_and_render() {
        let mut s = AutoscaleStats::default();
        assert_eq!(s.cell(), "-");
        s.absorb_scale_up(&up(true, true));
        s.absorb_scale_up(&up(false, false)); // unapplied: counted apart
        let pass = ConsolidationPass {
            considered: 2,
            removed: vec![NodeId(3)],
            moves: 2,
            drained_pods: 1,
            ..Default::default()
        };
        s.absorb_consolidation(&pass);
        assert_eq!(s.scale_ups, 1);
        assert_eq!(s.scale_up_unknown, 1, "the unapplied attempt is visible");
        assert_eq!(s.certified_scale_ups, 1);
        assert_eq!(s.nodes_added, 2);
        assert_eq!(s.scale_downs, 1);
        assert_eq!(s.nodes_removed, 1);
        assert_eq!(s.cell(), "+2/-1 cost=10");
        let mut t = AutoscaleStats::default();
        t.merge(&s);
        assert_eq!(t, s);
        assert!(t.any_activity());
    }

    #[test]
    fn consolidation_lines_cover_both_outcomes() {
        let idle = ConsolidationPass {
            considered: 3,
            blocked: 2,
            vetoed_budget: 1,
            ..Default::default()
        };
        assert_eq!(
            consolidation_log_line(&idle, &[]),
            "scale-down none (considered=3 blocked=2 budget-veto=1)"
        );
        let active = ConsolidationPass {
            considered: 1,
            removed: vec![NodeId(2)],
            moves: 2,
            drained_pods: 1,
            ..Default::default()
        };
        assert_eq!(
            consolidation_log_line(&active, &["node-002".to_string()]),
            "scale-down removed=1 (node-002) moves=2 drained=1"
        );
    }
}
