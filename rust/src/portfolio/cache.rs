//! Certificate cache for incremental solve sessions.
//!
//! Long-running drivers (the churn loop's fallback plugin, the periodic
//! defragmentation sweep) re-solve near-identical instances every cycle.
//! This cache lets [`solve_portfolio_session`](super::solve_portfolio_session)
//! skip work it has already *proven*:
//!
//! * **per-solve entries** — one per (model, objective, solver config)
//!   fingerprint: a whole phase solve whose inputs are unchanged replays
//!   its recorded solution and optimality certificate without invoking
//!   the solver at all;
//! * **per-component entries** — one per decomposed constraint-graph
//!   component: when only part of the cluster churned, the clean
//!   components replay from cache and only the dirty ones re-race.
//!
//! # Why only proven results are cached
//!
//! The determinism contract of the session layer is that a warm re-solve
//! is **byte-identical** to a cold solve of the same state: caching may
//! change how fast the answer arrives, never which answer. A proven
//! (`Optimal` / `Infeasible`) result is a pure function of the model and
//! config — any completing cold solve reproduces it bit for bit (the
//! PR 3 thread-independence contract). An *anytime* result, by contrast,
//! depends on the deadline it was truncated at, so replaying it could
//! diverge from what a fresh solve would return; anytime results are
//! therefore never stored, and a dirty window re-solves cold.
//!
//! Fingerprints deliberately exclude the deadline and the worker count:
//! completed results are independent of both (the same caveat the churn
//! replay digests carry).

use std::collections::BTreeMap;

use crate::solver::{CmpOp, LinearExpr, Model, SolveStatus, SolverConfig};
use crate::util::fingerprint::Fnv64;

use super::{ComponentReport, PortfolioConfig};

/// One cached whole-solve result (status is always proven).
#[derive(Clone, Debug)]
pub(crate) struct CachedSolve {
    pub status: SolveStatus,
    pub objective: i64,
    pub bound: i64,
    pub values: Vec<bool>,
    pub components: Vec<ComponentReport>,
}

/// One cached per-component result (status is always proven).
#[derive(Clone, Debug)]
pub(crate) struct CachedComponent {
    pub report: ComponentReport,
    /// Local (dense) assignment; empty iff the component is infeasible.
    pub values: Vec<bool>,
}

/// Cache observability counters, surfaced through
/// [`SolveSession`](crate::optimizer::session::SolveSession) into churn
/// reports.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheStats {
    /// Whole solves answered from cache (zero solver invocations).
    pub solve_hits: u64,
    /// Whole solves that missed and ran the solver.
    pub solve_misses: u64,
    /// Decomposed components replayed from cache.
    pub component_hits: u64,
    /// Decomposed components that re-raced.
    pub component_misses: u64,
    /// Proven whole-solve results stored.
    pub stored_solves: u64,
    /// Proven component results stored.
    pub stored_components: u64,
    /// Warm-start incumbent floors seeded from projected hints.
    pub warm_seeds: u64,
}

/// Bound on total cached entries (solves + components). The cap only
/// affects speed, never answers: overflow clears the cache, and a
/// cleared cache merely re-solves cold. Sized far above any realistic
/// churn working set (tiers × phases × components per cycle).
const MAX_ENTRIES: usize = 8192;

/// The certificate cache one [`SolveSession`] owns.
///
/// [`SolveSession`]: crate::optimizer::session::SolveSession
#[derive(Debug, Default)]
pub struct SolveCache {
    solves: BTreeMap<u64, CachedSolve>,
    components: BTreeMap<u64, CachedComponent>,
    pub stats: CacheStats,
}

impl SolveCache {
    pub fn new() -> Self {
        SolveCache::default()
    }

    /// Total cached entries (solves + components).
    pub fn len(&self) -> usize {
        self.solves.len() + self.components.len()
    }

    pub fn is_empty(&self) -> bool {
        self.solves.is_empty() && self.components.is_empty()
    }

    /// Drop every cached entry (config changes invalidate certificates).
    pub fn clear(&mut self) {
        self.solves.clear();
        self.components.clear();
    }

    pub(crate) fn lookup_solve(&mut self, fp: u64) -> Option<CachedSolve> {
        let hit = self.solves.get(&fp).cloned();
        match hit {
            Some(_) => self.stats.solve_hits += 1,
            None => self.stats.solve_misses += 1,
        }
        hit
    }

    pub(crate) fn store_solve(&mut self, fp: u64, entry: CachedSolve) {
        debug_assert!(matches!(entry.status, SolveStatus::Optimal | SolveStatus::Infeasible));
        self.evict_if_full();
        self.stats.stored_solves += 1;
        self.solves.insert(fp, entry);
    }

    pub(crate) fn lookup_component(&mut self, fp: u64) -> Option<CachedComponent> {
        let hit = self.components.get(&fp).cloned();
        match hit {
            Some(_) => self.stats.component_hits += 1,
            None => self.stats.component_misses += 1,
        }
        hit
    }

    pub(crate) fn store_component(&mut self, fp: u64, entry: CachedComponent) {
        debug_assert!(matches!(
            entry.report.status,
            SolveStatus::Optimal | SolveStatus::Infeasible
        ));
        self.evict_if_full();
        self.stats.stored_components += 1;
        self.components.insert(fp, entry);
    }

    fn evict_if_full(&mut self) {
        if self.len() >= MAX_ENTRIES {
            self.clear();
        }
    }
}

/// Fingerprint one solve's complete input: the model (constraints,
/// hints, resource classes), the objective, and every solver/portfolio
/// knob that can change a *completed* answer. Excluded on purpose:
/// `threads` and the deadline — completed results are independent of
/// both by the portfolio determinism contract.
pub fn fingerprint_solve(
    model: &Model,
    objective: &LinearExpr,
    solver: &SolverConfig,
    cfg: &PortfolioConfig,
) -> u64 {
    let mut h = Fnv64::new();
    h.tag(b'M').write_usize(model.num_vars());
    h.write_usize(model.constraints.len());
    for c in &model.constraints {
        h.tag(match c.op {
            CmpOp::Le => 0,
            CmpOp::Ge => 1,
            CmpOp::Eq => 2,
        });
        h.write_i64(c.rhs).write_usize(c.expr.terms.len());
        for &(v, coef) in &c.expr.terms {
            h.write_u32(v.0).write_i64(coef);
        }
    }
    h.tag(b'H');
    for (i, hint) in model.hints.iter().enumerate() {
        if let Some(val) = hint {
            h.write_usize(i).write_bool(*val);
        }
    }
    h.tag(b'R').write_usize(model.resource_classes.len());
    for class in &model.resource_classes {
        h.write_str(&class.name).write_usize(class.cons.len());
        for &ci in &class.cons {
            h.write_u32(ci);
        }
    }
    h.tag(b'O').write_usize(objective.terms.len());
    for &(v, coef) in &objective.terms {
        h.write_u32(v.0).write_i64(coef);
    }
    h.tag(b'S')
        .write_bool(solver.use_bound)
        .write_bool(solver.use_capacity_bound)
        .write_bool(solver.use_hints)
        .write_bool(solver.use_best_fit)
        .write_bool(solver.use_symmetry)
        .write_bool(solver.use_lns)
        .write_f64(solver.lns_fraction)
        .write_bool(solver.branch_easiest_first)
        .write_u64(solver.check_interval)
        .write_u64(solver.seed);
    h.tag(b'P')
        .write_bool(cfg.decompose)
        .write_usize(cfg.strategies);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> (Model, LinearExpr) {
        let mut m = Model::new();
        let x = m.new_var();
        let y = m.new_var();
        m.add_le(LinearExpr::of([(x, 1), (y, 1)]), 1);
        let obj = LinearExpr::of([(x, 1), (y, 1)]);
        (m, obj)
    }

    #[test]
    fn identical_inputs_share_a_fingerprint() {
        let (m, obj) = tiny_model();
        let (m2, obj2) = tiny_model();
        let s = SolverConfig::default();
        let p = PortfolioConfig::with_threads(1);
        assert_eq!(
            fingerprint_solve(&m, &obj, &s, &p),
            fingerprint_solve(&m2, &obj2, &s, &p)
        );
    }

    #[test]
    fn model_hint_and_config_changes_alter_the_fingerprint() {
        let (mut m, obj) = tiny_model();
        let s = SolverConfig::default();
        let p = PortfolioConfig::with_threads(1);
        let base = fingerprint_solve(&m, &obj, &s, &p);

        m.hint(crate::solver::VarId(0), true);
        let hinted = fingerprint_solve(&m, &obj, &s, &p);
        assert_ne!(base, hinted, "hints are solve input");

        let other_seed = SolverConfig {
            seed: 99,
            ..SolverConfig::default()
        };
        assert_ne!(hinted, fingerprint_solve(&m, &obj, &other_seed, &p));
    }

    #[test]
    fn thread_count_does_not_alter_the_fingerprint() {
        let (m, obj) = tiny_model();
        let s = SolverConfig::default();
        assert_eq!(
            fingerprint_solve(&m, &obj, &s, &PortfolioConfig::with_threads(1)),
            fingerprint_solve(&m, &obj, &s, &PortfolioConfig::with_threads(8)),
        );
    }

    #[test]
    fn lookup_and_store_track_stats() {
        let mut cache = SolveCache::new();
        assert!(cache.lookup_solve(42).is_none());
        cache.store_solve(
            42,
            CachedSolve {
                status: SolveStatus::Optimal,
                objective: 3,
                bound: 3,
                values: vec![true],
                components: Vec::new(),
            },
        );
        let hit = cache.lookup_solve(42).expect("stored entry");
        assert_eq!(hit.objective, 3);
        assert_eq!(cache.stats.solve_hits, 1);
        assert_eq!(cache.stats.solve_misses, 1);
        assert_eq!(cache.stats.stored_solves, 1);
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }
}
