//! Constraint-graph decomposition: split a model into independent
//! components solvable in isolation.
//!
//! Two variables are *connected* when some constraint mentions both —
//! in the packing models this means pods sharing a candidate node's
//! capacity row, an anti-affinity pair, a spread group, or an
//! accumulated phase-lock row. The connected components of that graph
//! are fully independent sub-problems: no constraint spans two
//! components, so any per-component feasible assignments compose into a
//! whole-model feasible assignment, objectives add, and — crucially —
//! **per-component optimality certificates compose into a whole-model
//! certificate** (if each component is solved to its proven optimum, the
//! merged solution provably maximises the separable objective).
//!
//! When does a packing instance actually split? Whenever the candidate
//! node sets partition: taint/toleration pools, node-selector groups,
//! drained sections of the cluster. The paper's unconstrained workload
//! (every pod admissible on every node) stays one component — the
//! portfolio then degrades gracefully to a pure strategy race. Note the
//! phase-lock rows Algorithm 1 appends after a tier's first solve span
//! every eligible pod, so decomposition bites hardest on each tier's
//! *first* phase-1 solve — exactly the deadline-critical placement
//! maximisation the paper's headline improvement rates measure.
//!
//! Variable-free constraints (`0 op rhs`, e.g. a lock over an empty
//! metric) belong to no component; they are checked once here and either
//! hold for every assignment or make the whole model infeasible.

use crate::solver::{CmpOp, LinearExpr, Model, VarId};

/// One independent sub-problem of a decomposed model.
#[derive(Clone, Debug)]
pub struct Component {
    /// Original variable ids owned by this component, ascending.
    pub vars: Vec<u32>,
    /// Original constraint indices owned by this component, ascending.
    pub cons: Vec<u32>,
    /// Standalone model: variables renumbered densely in ascending
    /// original order, constraint order preserved, hints and resource
    /// classes carried over. Identical search behaviour to the same
    /// variables inside the whole model, minus the other components.
    pub model: Model,
    /// The original objective restricted to this component's variables.
    pub objective: LinearExpr,
}

/// Result of [`decompose`].
#[derive(Clone, Debug)]
pub struct Decomposition {
    /// Components ordered by their smallest original variable id —
    /// deterministic from the model alone.
    pub components: Vec<Component>,
    /// Some variable-free constraint (`0 op rhs`) is violated: the model
    /// is infeasible before any variable is assigned.
    pub constant_infeasible: bool,
}

impl Decomposition {
    /// Scatter a component's local solution into a whole-model
    /// assignment vector.
    pub fn scatter(&self, component: usize, local: &[bool], into: &mut [bool]) {
        let comp = &self.components[component];
        debug_assert_eq!(local.len(), comp.vars.len());
        for (li, &ov) in comp.vars.iter().enumerate() {
            into[ov as usize] = local[li];
        }
    }
}

/// Union-find over variable indices with path halving and min-root
/// union (the smaller root wins, keeping roots deterministic).
struct Dsu {
    parent: Vec<u32>,
}

impl Dsu {
    fn new(n: usize) -> Dsu {
        Dsu {
            parent: (0..n as u32).collect(),
        }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            self.parent[x as usize] = self.parent[self.parent[x as usize] as usize];
            x = self.parent[x as usize];
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra != rb {
            let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
            self.parent[hi as usize] = lo;
        }
    }
}

/// Union every constraint's variables; returns the filled union-find
/// plus whether some variable-free constraint is violated.
fn build_dsu(model: &Model) -> (Dsu, bool) {
    let mut dsu = Dsu::new(model.num_vars());
    let mut constant_infeasible = false;
    for c in &model.constraints {
        match c.expr.terms.first() {
            None => {
                let holds = match c.op {
                    CmpOp::Le => c.rhs >= 0,
                    CmpOp::Ge => c.rhs <= 0,
                    CmpOp::Eq => c.rhs == 0,
                };
                if !holds {
                    constant_infeasible = true;
                }
            }
            Some(&(v0, _)) => {
                for &(v, _) in &c.expr.terms[1..] {
                    dsu.union(v0.0, v.0);
                }
            }
        }
    }
    (dsu, constant_infeasible)
}

/// Result of the cheap connectivity probe — hand it to
/// [`decompose_probed`] to avoid rebuilding the union-find.
pub struct Probe {
    dsu: Dsu,
    /// Number of connected components.
    pub components: usize,
    /// Some variable-free constraint (`0 op rhs`) is violated.
    pub constant_infeasible: bool,
}

/// Cheap probe: count connected components and validate variable-free
/// constraints — **without** building any sub-model. The portfolio
/// calls this first so the common single-component case (plain
/// workloads, every lock-coupled phase-2 model) never pays for
/// sub-model construction inside the solve window; the probe's
/// union-find is reused by [`decompose_probed`] when splitting does
/// happen.
pub fn probe(model: &Model) -> Probe {
    let nv = model.num_vars();
    let (mut dsu, constant_infeasible) = build_dsu(model);
    let mut seen = vec![false; nv];
    let mut components = 0usize;
    for v in 0..nv as u32 {
        let root = dsu.find(v) as usize;
        if !seen[root] {
            seen[root] = true;
            components += 1;
        }
    }
    Probe {
        dsu,
        components,
        constant_infeasible,
    }
}

/// [`probe`] reduced to its two scalar answers.
pub fn component_count(model: &Model) -> (usize, bool) {
    let p = probe(model);
    (p.components, p.constant_infeasible)
}

/// Split `model` into independent components (see module docs).
pub fn decompose(model: &Model, objective: &LinearExpr) -> Decomposition {
    decompose_probed(model, objective, probe(model))
}

/// [`decompose`] reusing an existing [`Probe`]'s union-find.
pub fn decompose_probed(model: &Model, objective: &LinearExpr, probe: Probe) -> Decomposition {
    let nv = model.num_vars();
    let Probe {
        mut dsu,
        constant_infeasible,
        ..
    } = probe;

    // Component ids in order of first appearance over ascending variable
    // id; local (dense) ids follow the same ascending order.
    let mut comp_of_root: Vec<u32> = vec![u32::MAX; nv];
    let mut comp_of_var: Vec<u32> = vec![u32::MAX; nv];
    let mut local_of_var: Vec<u32> = vec![0; nv];
    let mut vars_per_comp: Vec<Vec<u32>> = Vec::new();
    for v in 0..nv as u32 {
        let root = dsu.find(v) as usize;
        if comp_of_root[root] == u32::MAX {
            comp_of_root[root] = vars_per_comp.len() as u32;
            vars_per_comp.push(Vec::new());
        }
        let k = comp_of_root[root];
        comp_of_var[v as usize] = k;
        let members = &mut vars_per_comp[k as usize];
        local_of_var[v as usize] = members.len() as u32;
        members.push(v);
    }

    let mut components: Vec<Component> = vars_per_comp
        .into_iter()
        .map(|vars| {
            let mut m = Model::new();
            let ids = m.new_vars(vars.len());
            for (li, &ov) in vars.iter().enumerate() {
                if let Some(hint) = model.hints[ov as usize] {
                    m.hint(ids[li], hint);
                }
            }
            Component {
                vars,
                cons: Vec::new(),
                model: m,
                objective: LinearExpr::new(),
            }
        })
        .collect();

    // Constraints, in original order, each remapped into its component.
    let nc = model.constraints.len();
    let mut comp_of_cons: Vec<u32> = vec![u32::MAX; nc];
    let mut local_of_cons: Vec<u32> = vec![0; nc];
    for (ci, c) in model.constraints.iter().enumerate() {
        let Some(&(v0, _)) = c.expr.terms.first() else {
            continue; // constant: validated above, owned by nobody
        };
        let k = comp_of_var[v0.idx()] as usize;
        debug_assert!(
            c.expr.terms.iter().all(|&(v, _)| comp_of_var[v.idx()] == k as u32),
            "constraint spans components"
        );
        comp_of_cons[ci] = k as u32;
        local_of_cons[ci] = components[k].model.next_constraint_index() as u32;
        components[k].cons.push(ci as u32);
        let expr = LinearExpr::of(
            c.expr
                .terms
                .iter()
                .map(|&(v, coef)| (VarId(local_of_var[v.idx()]), coef)),
        );
        components[k].model.add_constraint(expr, c.op, c.rhs);
    }

    // Resource classes split along component lines: a class spanning
    // several components contributes its local rows to each (the
    // aggregate capacity bound stays admissible on the restriction).
    for class in &model.resource_classes {
        for (k, comp) in components.iter_mut().enumerate() {
            let cons: Vec<usize> = class
                .cons
                .iter()
                .filter(|&&ci| comp_of_cons[ci as usize] == k as u32)
                .map(|&ci| local_of_cons[ci as usize] as usize)
                .collect();
            if !cons.is_empty() {
                comp.model.add_named_resource_class(class.name.clone(), cons);
            }
        }
    }

    // Objective restricted per component.
    for &(v, coef) in &objective.clone().normalized().terms {
        let k = comp_of_var[v.idx()] as usize;
        components[k]
            .objective
            .add(VarId(local_of_var[v.idx()]), coef);
    }

    Decomposition {
        components,
        constant_infeasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two pods × two disjoint node pools: pod A can use nodes {0,1},
    /// pod B nodes {2,3} — two components.
    fn split_model() -> (Model, LinearExpr, Vec<VarId>, Vec<VarId>) {
        let mut m = Model::new();
        let a = m.new_vars(2);
        let b = m.new_vars(2);
        m.add_le(LinearExpr::of(a.iter().map(|&v| (v, 1))), 1);
        m.add_le(LinearExpr::of(b.iter().map(|&v| (v, 1))), 1);
        let c0 = m.next_constraint_index();
        m.add_le(LinearExpr::of([(a[0], 500)]), 1000);
        let c1 = m.next_constraint_index();
        m.add_le(LinearExpr::of([(b[0], 500)]), 1000);
        m.add_named_resource_class("cpu", [c0, c1]);
        m.hint(a[1], true);
        let obj = LinearExpr::of(a.iter().chain(&b).map(|&v| (v, 1)));
        (m, obj, a, b)
    }

    #[test]
    fn disjoint_pools_split_into_two_components() {
        let (m, obj, a, b) = split_model();
        let d = decompose(&m, &obj);
        assert!(!d.constant_infeasible);
        assert_eq!(d.components.len(), 2);
        let ca = &d.components[0];
        let cb = &d.components[1];
        assert_eq!(ca.vars, vec![a[0].0, a[1].0]);
        assert_eq!(cb.vars, vec![b[0].0, b[1].0]);
        // each side owns its at-most-one row and its capacity row
        assert_eq!(ca.cons, vec![0, 2]);
        assert_eq!(cb.cons, vec![1, 3]);
        assert_eq!(ca.model.constraints.len(), 2);
        // hint on a[1] carried to local id 1 of component 0
        assert_eq!(ca.model.hints, vec![None, Some(true)]);
        assert_eq!(cb.model.hints, vec![None, None]);
        // the shared "cpu" class split into one row per side
        assert_eq!(ca.model.resource_classes.len(), 1);
        assert_eq!(ca.model.resource_classes[0].cons, vec![1]);
        assert_eq!(cb.model.resource_classes[0].cons, vec![1]);
        // objective restricted: two unit terms per side
        assert_eq!(ca.objective.terms.len(), 2);
        assert_eq!(cb.objective.terms.len(), 2);
    }

    #[test]
    fn scatter_maps_local_back_to_original_ids() {
        let (m, obj, a, b) = split_model();
        let d = decompose(&m, &obj);
        let mut whole = vec![false; m.num_vars()];
        d.scatter(0, &[true, false], &mut whole);
        d.scatter(1, &[false, true], &mut whole);
        assert!(whole[a[0].idx()]);
        assert!(!whole[a[1].idx()]);
        assert!(!whole[b[0].idx()]);
        assert!(whole[b[1].idx()]);
    }

    #[test]
    fn shared_constraint_keeps_one_component() {
        let (mut m, obj, a, b) = split_model();
        // one row touching both pods glues everything together
        m.add_le(LinearExpr::of([(a[0], 1), (b[0], 1)]), 1);
        let d = decompose(&m, &obj);
        assert_eq!(d.components.len(), 1);
        assert_eq!(d.components[0].vars.len(), m.num_vars());
        assert_eq!(d.components[0].cons.len(), m.constraints.len());
    }

    #[test]
    fn violated_constant_constraint_flags_infeasible() {
        let mut m = Model::new();
        let _x = m.new_var();
        m.add_ge(LinearExpr::new(), 1); // 0 >= 1
        let d = decompose(&m, &LinearExpr::new());
        assert!(d.constant_infeasible);
        // satisfiable constants do not
        let mut m2 = Model::new();
        let _y = m2.new_var();
        m2.add_le(LinearExpr::new(), 0); // 0 <= 0
        assert!(!decompose(&m2, &LinearExpr::new()).constant_infeasible);
    }

    #[test]
    fn component_count_matches_full_decomposition() {
        let (m, obj, _, _) = split_model();
        let (count, infeasible) = component_count(&m);
        assert_eq!(count, decompose(&m, &obj).components.len());
        assert!(!infeasible);
        let mut m2 = Model::new();
        let _x = m2.new_var();
        m2.add_ge(LinearExpr::new(), 1);
        assert_eq!(component_count(&m2), (1, true));
        assert_eq!(component_count(&Model::new()), (0, false));
    }

    #[test]
    fn isolated_variables_become_singleton_components() {
        let mut m = Model::new();
        let xs = m.new_vars(3); // no constraints at all
        let obj = LinearExpr::of(xs.iter().map(|&v| (v, 1)));
        let d = decompose(&m, &obj);
        assert_eq!(d.components.len(), 3);
        for (k, comp) in d.components.iter().enumerate() {
            assert_eq!(comp.vars, vec![k as u32]);
            assert!(comp.cons.is_empty());
            assert_eq!(comp.objective.terms.len(), 1);
        }
    }
}
