//! Deterministic multi-threaded execution of a portfolio task list.
//!
//! The task list is fixed *before* any thread starts — it never depends
//! on the worker count — and workers merely pull tasks off a shared
//! counter. Coordination is limited to two mechanisms that provably
//! cannot change a completing task's answer (see
//! [`crate::solver::SharedIncumbent`]):
//!
//! * a per-component incumbent floor racers prune **strictly** against;
//! * cancellation of *strictly higher ranks* once a task proves its
//!   component exact (Optimal or Infeasible). A cancelled task could at
//!   best have tied the prover's objective, and ties resolve to the
//!   lower rank anyway — so whether the cancellation lands before or
//!   after the rival ran is unobservable in the selected winner.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::solver::{
    solve_max_probed, solve_max_with, LinearExpr, Model, Probe, SharedIncumbent, SolveStatus,
    Solution, SolverConfig,
};
use crate::telemetry::{clock::Deadline, Telemetry};

/// One racer's assignment.
pub(crate) struct Task<'a> {
    /// Component this task races (`None` = the whole-model anchor).
    pub component: Option<usize>,
    /// Rank within the component's roster; ties resolve to the lowest.
    pub rank: u32,
    pub label: &'static str,
    pub model: &'a Model,
    pub objective: &'a LinearExpr,
    pub config: SolverConfig,
}

/// Warm-start incumbent floors an incremental solve session seeds into
/// the race: the objective value of the previous incumbent *projected*
/// onto the current model (feasibility-checked by the caller). Racers
/// prune **strictly** below the floor, so a seed — always some feasible
/// assignment's objective, hence never above the true optimum — can only
/// accelerate a completing racer, never change its answer (see
/// [`SharedIncumbent`]'s determinism note).
#[derive(Clone, Debug, Default)]
pub(crate) struct WarmSeeds {
    /// Floor for the whole-model anchor task.
    pub whole: Option<i64>,
    /// Floor per component, indexed by original component id.
    pub per_component: Vec<Option<i64>>,
}

impl WarmSeeds {
    /// Number of floors this seed set will publish.
    pub fn count(&self) -> u64 {
        u64::from(self.whole.is_some()) + self.per_component.iter().flatten().count() as u64
    }
}

/// Run every task under `deadline` on up to `threads` workers. Returns
/// one result slot per task (`None` = cancelled before it started) plus
/// the number of cancelled-unstarted tasks.
///
/// Telemetry: each task gets a [`Telemetry::child`] lane, created here
/// in task order (before any worker spawns) and absorbed back in task
/// order after the scope — the merged record is a pure function of the
/// task list, whatever the thread interleaving did.
///
/// Forensics: an armed [`Probe`] records exactly one task — the first
/// with `component == None` (the whole-model anchor / forensic lane) —
/// through a [`Probe::child`] handle created before any worker spawns
/// and absorbed once after the scope. One lane, one absorb: the profile
/// is a pure function of that task's deterministic search.
pub(crate) fn run_race(
    tasks: &[Task<'_>],
    deadline: Deadline,
    threads: usize,
    warm: Option<&WarmSeeds>,
    tel: &Telemetry,
    prof: &Probe,
) -> (Vec<Option<Solution>>, u64) {
    let n = tasks.len();
    if n == 0 {
        return (Vec::new(), 0);
    }
    let ncomp = tasks
        .iter()
        .filter_map(|t| t.component)
        .map(|c| c + 1)
        .max()
        .unwrap_or(0);
    // One floor per component; every task gets its own sibling handle
    // (shared floor, private cancellation flag).
    let floors: Vec<SharedIncumbent> = (0..ncomp).map(|_| SharedIncumbent::new()).collect();
    // The anchor keeps its floor-free cold behaviour unless a session
    // seeds it: its floor is never shared with component racers (their
    // objectives live on different scales).
    let anchor_floor: Option<SharedIncumbent> =
        warm.and_then(|w| w.whole).map(SharedIncumbent::seeded);
    if let Some(w) = warm {
        for (c, floor) in floors.iter().enumerate() {
            if let Some(&Some(v)) = w.per_component.get(c) {
                floor.publish(v);
            }
        }
    }
    let handles: Vec<Option<SharedIncumbent>> = tasks
        .iter()
        .map(|t| match t.component {
            Some(c) => Some(floors[c].sibling()),
            None => anchor_floor.as_ref().map(|f| f.sibling()),
        })
        .collect();
    let cancels: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Solution>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let workers = threads.clamp(1, n);

    // The canonical forensic lane: the first component-`None` task, if
    // any. Its child probe inherits the caller's context frames; workers
    // push `exact` on top so the folded paths match the `threads = 1`
    // legacy lane byte for byte.
    let canonical = tasks.iter().position(|t| t.component.is_none());
    let prof_lane: Mutex<Probe> = Mutex::new(prof.child());

    // One telemetry lane per task, allocated here on the owning thread
    // so lane numbering is deterministic. Off handles cost nothing.
    let lanes: Vec<Mutex<Telemetry>> = tasks
        .iter()
        .map(|t| {
            Mutex::new(tel.child(&match t.component {
                Some(c) => format!("{} c{c} r{}", t.label, t.rank),
                None => t.label.to_string(),
            }))
        })
        .collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                if cancels[i].load(Ordering::Relaxed) {
                    continue; // a lower rank already proved this component
                }
                let task = &tasks[i];
                let lane = lanes[i].lock().expect("telemetry lane poisoned");
                let sp = lane.span("race-task");
                sp.arg("strategy", task.label);
                if let Some(c) = task.component {
                    sp.arg("component", c);
                }
                sp.arg("rank", task.rank);
                // detlint: allow(wall-clock) — per-strategy latency histogram
                // stamp: pure observability, placement bytes unaffected.
                let started = std::time::Instant::now();
                let sol = if Some(i) == canonical {
                    let probe = prof_lane.lock().expect("probe lane poisoned");
                    let _pf = probe.frame("exact");
                    solve_max_probed(
                        task.model,
                        task.objective,
                        deadline,
                        &task.config,
                        handles[i].as_ref(),
                        &probe,
                    )
                } else {
                    solve_max_with(
                        task.model,
                        task.objective,
                        deadline,
                        &task.config,
                        handles[i].as_ref(),
                    )
                };
                sp.arg("status", sol.status.label());
                if lane.enabled() {
                    sol.stats
                        .record(&lane, &format!("strategy=\"{}\"", task.label));
                    lane.observe_us(
                        "race_task_seconds",
                        &format!("strategy=\"{}\"", task.label),
                        started.elapsed().as_micros() as u64,
                    );
                }
                drop(sp);
                drop(lane);
                if matches!(sol.status, SolveStatus::Optimal | SolveStatus::Infeasible) {
                    // Exactness proven: *higher* ranks on this component
                    // can at best tie and lose the tie-break — release
                    // their threads for useful work. Lower ranks keep
                    // running so their (deterministic) answers stay
                    // available to the tie-break.
                    if let Some(c) = task.component {
                        for (j, other) in tasks.iter().enumerate() {
                            if other.component == Some(c) && other.rank > task.rank {
                                cancels[j].store(true, Ordering::Relaxed);
                                if let Some(handle) = &handles[j] {
                                    handle.cancel();
                                }
                            }
                        }
                    }
                }
                *results[i].lock().expect("result slot poisoned") = Some(sol);
            });
        }
    });

    // Absorb task lanes in task-index order — never completion order.
    for lane in lanes {
        tel.absorb(lane.into_inner().expect("telemetry lane poisoned"));
    }
    prof.absorb(prof_lane.into_inner().expect("probe lane poisoned"));

    let mut out = Vec::with_capacity(n);
    let mut cancelled = 0u64;
    for (i, slot) in results.into_iter().enumerate() {
        let sol = slot.into_inner().expect("result slot poisoned");
        if sol.is_none() && cancels[i].load(Ordering::Relaxed) {
            cancelled += 1;
        }
        out.push(sol);
    }
    (out, cancelled)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two-node figure-1 packing model with a unit objective.
    fn model() -> (Model, LinearExpr) {
        let mut m = Model::new();
        let pods = [2048i64, 2048, 3072];
        let mut vars = Vec::new();
        for _ in &pods {
            let xs = m.new_vars(2);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            vars.push(xs);
        }
        for node in 0..2 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&pods).map(|(xs, &r)| (xs[node], r))),
                4096,
            );
        }
        let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
        (m, obj)
    }

    #[test]
    fn race_results_are_deterministic_across_reruns_and_thread_counts() {
        let (m, obj) = model();
        let mk_tasks = || {
            vec![
                Task {
                    component: Some(0),
                    rank: 0,
                    label: "default",
                    model: &m,
                    objective: &obj,
                    config: SolverConfig::default(),
                },
                Task {
                    component: Some(0),
                    rank: 1,
                    label: "greedy-warm",
                    model: &m,
                    objective: &obj,
                    config: SolverConfig {
                        use_best_fit: false,
                        use_lns: false,
                        ..Default::default()
                    },
                },
            ]
        };
        let runs: Vec<_> = [1usize, 2, 8]
            .iter()
            .map(|&t| {
                run_race(
                    &mk_tasks(),
                    Deadline::unlimited(),
                    t,
                    None,
                    &Telemetry::off(),
                    &Probe::off(),
                )
                .0
            })
            .collect();
        for run in &runs {
            // rank 0 always runs (never cancelled by construction)
            let r0 = run[0].as_ref().expect("rank 0 ran");
            assert_eq!(r0.status, SolveStatus::Optimal);
            assert_eq!(r0.objective, 3);
            if let Some(r1) = &run[1] {
                // rank 1 may have been cancelled mid-run by rank 0's
                // proof; whatever it reports can only tie, never exceed
                assert!(r1.objective <= 3);
            }
        }
        // rank 0's answer is identical whatever the worker count
        let v0: Vec<_> = runs
            .iter()
            .map(|r| r[0].as_ref().unwrap().values.clone())
            .collect();
        assert_eq!(v0[0], v0[1]);
        assert_eq!(v0[1], v0[2]);
    }

    #[test]
    fn pre_proven_component_cancels_higher_ranks_on_one_worker() {
        // With one worker the rank-0 task completes (proving optimality)
        // before rank 1 is even picked up: rank 1 must come back `None`
        // and be counted as cancelled.
        let (m, obj) = model();
        let tasks = vec![
            Task {
                component: Some(0),
                rank: 0,
                label: "default",
                model: &m,
                objective: &obj,
                config: SolverConfig::default(),
            },
            Task {
                component: Some(0),
                rank: 1,
                label: "greedy-warm",
                model: &m,
                objective: &obj,
                config: SolverConfig::default(),
            },
        ];
        let (results, cancelled) = run_race(
            &tasks,
            Deadline::unlimited(),
            1,
            None,
            &Telemetry::off(),
            &Probe::off(),
        );
        assert!(results[0].is_some());
        assert!(results[1].is_none());
        assert_eq!(cancelled, 1);
    }

    #[test]
    fn seeded_floor_does_not_change_a_completing_race() {
        // Seed the component floor with the true optimum (3): strict
        // pruning must leave the completing racer's answer untouched —
        // the warm-start invariant the session layer relies on.
        let (m, obj) = model();
        let mk_tasks = || {
            vec![Task {
                component: Some(0),
                rank: 0,
                label: "default",
                model: &m,
                objective: &obj,
                config: SolverConfig::default(),
            }]
        };
        let cold = run_race(
            &mk_tasks(),
            Deadline::unlimited(),
            2,
            None,
            &Telemetry::off(),
            &Probe::off(),
        )
        .0;
        let seeds = WarmSeeds {
            whole: None,
            per_component: vec![Some(3)],
        };
        assert_eq!(seeds.count(), 1);
        let warm = run_race(
            &mk_tasks(),
            Deadline::unlimited(),
            2,
            Some(&seeds),
            &Telemetry::off(),
            &Probe::off(),
        )
        .0;
        let c = cold[0].as_ref().expect("cold racer ran");
        let w = warm[0].as_ref().expect("warm racer ran");
        assert_eq!(w.status, SolveStatus::Optimal);
        assert_eq!(w.objective, c.objective);
        assert_eq!(w.values, c.values);
    }

    #[test]
    fn armed_probe_records_only_the_canonical_lane() {
        // Anchor (component None) plus one component racer: the probe
        // must capture the anchor's search under `exact` and record
        // nothing from the racer, whatever the worker count.
        let (m, obj) = model();
        let mk_tasks = || {
            vec![
                Task {
                    component: None,
                    rank: 0,
                    label: "whole-model",
                    model: &m,
                    objective: &obj,
                    config: SolverConfig::default(),
                },
                Task {
                    component: Some(0),
                    rank: 0,
                    label: "default",
                    model: &m,
                    objective: &obj,
                    config: SolverConfig::default(),
                },
            ]
        };
        let folded: Vec<String> = [1usize, 4]
            .iter()
            .map(|&t| {
                let prof = Probe::armed();
                let (results, _) = run_race(
                    &mk_tasks(),
                    Deadline::unlimited(),
                    t,
                    None,
                    &Telemetry::off(),
                    &prof,
                );
                let anchor = results[0].as_ref().expect("anchor ran");
                let decisions: u64 = prof
                    .module_effort()
                    .iter()
                    .filter(|(_, kind, _)| *kind == "decisions")
                    .map(|&(_, _, n)| n)
                    .sum();
                // exactly one lane recorded: the anchor's own decisions
                assert_eq!(decisions, anchor.stats.decisions);
                prof.export_folded()
            })
            .collect();
        assert!(folded[0].contains("solve;exact;"));
        // deterministic forensics: identical profile at 1 and 4 workers
        assert_eq!(folded[0], folded[1]);
    }
}
