//! Parallel portfolio solving with constraint-graph decomposition — the
//! layer between the optimiser (Algorithm 1) and the CP solver core.
//!
//! The paper's headline numbers are deadline-bound: within a 1-second
//! window the CP fallback improves 44% of scenarios, within 10 seconds
//! 73% — so search throughput inside the window converts directly into
//! more improved and more *certified* placements. This subsystem
//! saturates the machine inside the same paper-faithful budget:
//!
//! 1. **Decomposition** ([`decompose`]): a presolve pass splits the
//!    per-tier packing model into independent constraint-graph
//!    components (pods/nodes connected through shared capacity rows,
//!    anti-affinity pairs, spread groups, …). Components are solved
//!    separately and merged; component-wise optimality certificates
//!    compose into a whole-instance certificate.
//! 2. **Portfolio race** ([`race`], [`strategy`]): per component, a
//!    fixed roster of diverse solver configurations (branching-order
//!    variants, LNS-heavy, greedy warm-started from the default
//!    scheduler's placement) races on `std::thread`-scoped workers under
//!    one shared deadline, pruning against a shared atomic incumbent
//!    floor and stopping early once a lower rank proves optimality.
//!
//! # Determinism contract
//!
//! Results are a pure function of the model, the seed, and the deadline
//! — **independent of the worker count** — whenever every racer
//! completes inside the window (the same caveat the churn replay
//! digests already carry for the anytime solver). The ingredients:
//!
//! * the task list is fixed before any thread starts and never depends
//!   on `threads`;
//! * winners are selected by *(objective, then fixed strategy rank)* —
//!   never by wall-clock arrival;
//! * the shared floor prunes **strictly**, so a completing racer returns
//!   the same first-in-DFS-order optimum it finds alone;
//! * a proof cancels only *strictly higher* ranks, whose results could
//!   at best have tied and lost the tie-break anyway;
//! * with more than one component, a **whole-model anchor** (the exact
//!   single-threaded solve, rank 0 overall) also runs and wins all ties
//!   — so on instances the deadline does not truncate, any `threads`
//!   value reproduces the single-threaded answer bit for bit.
//!
//! `threads == 1` (the default) does not spawn at all: it *is* the
//! single-threaded code path, byte-identical to calling
//! [`solve_max`](crate::solver::solve_max) directly.
//!
//! # Incremental sessions
//!
//! [`solve_portfolio_session`] threads an optional [`SolveCache`]
//! (owned by an [`optimizer::session::SolveSession`]) through the solve:
//! proven results replay from cache (whole solves and individual
//! decomposed components), and dirty work warm-starts from the previous
//! incumbent projected onto the model's hints, seeded as the race's
//! initial [`SharedIncumbent`](crate::solver::SharedIncumbent) floor.
//! Caching only ever replays *proven* certificates, so it can change how
//! fast an answer arrives but never which answer — see [`cache`].
//!
//! [`optimizer::session::SolveSession`]: crate::optimizer::session::SolveSession

pub mod cache;
pub mod decompose;
mod race;
pub mod strategy;

pub use cache::{fingerprint_solve, CacheStats, SolveCache};
pub use decompose::{component_count, decompose, Component, Decomposition};
pub use strategy::{roster, MAX_STRATEGIES};

use crate::solver::{
    solve_max, solve_max_probed, solve_max_with, LinearExpr, Model, Probe, SearchStats,
    SharedIncumbent, SolveStatus, Solution, SolverConfig,
};
use crate::telemetry::{clock::Deadline, Telemetry};

use cache::{CachedComponent, CachedSolve};
use race::{run_race, Task, WarmSeeds};

/// Label used for the whole-model anchor task in stats and reports.
pub const WHOLE_MODEL: &str = "whole-model";

/// Portfolio knobs, carried by `OptimizerConfig`.
#[derive(Clone, Debug, PartialEq)]
pub struct PortfolioConfig {
    /// Worker threads racing the portfolio. `1` bypasses the portfolio
    /// entirely — bit-for-bit the single-threaded solver. The default is
    /// `1` unless the `KUBE_PACKD_THREADS` environment variable says
    /// otherwise.
    pub threads: usize,
    /// Run the constraint-graph decomposition presolve (off = race
    /// strategies on the undecomposed model only).
    pub decompose: bool,
    /// Strategies raced per component (clamped to `1..=MAX_STRATEGIES`).
    pub strategies: usize,
}

impl Default for PortfolioConfig {
    fn default() -> Self {
        PortfolioConfig {
            threads: env_threads(),
            decompose: true,
            strategies: 3,
        }
    }
}

impl PortfolioConfig {
    /// Default knobs at an explicit thread count (0 is clamped to 1).
    pub fn with_threads(threads: usize) -> Self {
        PortfolioConfig {
            threads: threads.max(1),
            ..Default::default()
        }
    }
}

/// `KUBE_PACKD_THREADS` (≥ 1) or the single-threaded default.
fn env_threads() -> usize {
    std::env::var("KUBE_PACKD_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Per-component outcome of one portfolio solve, returned in
/// [`PortfolioOutcome`]. Aggregate counts flow onward into
/// [`PortfolioStats`] and per-tier summaries (`TierReport`'s
/// `phase1_components` / `phase1_components_certified`), which is what
/// the `solve --json` certificate report emits.
#[derive(Clone, Debug)]
pub struct ComponentReport {
    pub vars: usize,
    pub cons: usize,
    pub status: SolveStatus,
    pub objective: i64,
    /// Admissible upper bound on the component objective.
    pub bound: i64,
    /// Winning strategy label (`"-"` when no racer produced a solution).
    pub winner: &'static str,
}

/// Counters aggregated across portfolio solves (merged into
/// `OptimizeResult` / `RunReport`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PortfolioStats {
    /// Solves routed through the parallel portfolio (`threads > 1`).
    pub solves: u64,
    /// Solves answered by the single-threaded legacy path.
    pub legacy_solves: u64,
    /// Components across all portfolio solves.
    pub components: u64,
    /// Components whose optimum was proven inside the window.
    pub components_certified: u64,
    /// Strategy tasks actually executed.
    pub tasks_run: u64,
    /// Tasks skipped because a lower rank proved their component first.
    pub tasks_cancelled: u64,
    /// Final winners: the whole-model anchor vs the merged composite.
    pub whole_model_wins: u64,
    pub composite_wins: u64,
    /// Whole solves replayed from a session's certificate cache
    /// (zero solver invocations).
    pub cache_hits: u64,
    /// Decomposed components replayed from a session's certificate cache.
    pub component_cache_hits: u64,
    /// Warm-start incumbent floors seeded from projected hints.
    pub warm_starts: u64,
    /// Warm-seeded solves whose final objective equalled the seeded
    /// floor — the projected previous incumbent was already optimal for
    /// the new model, so the seed was a perfect guess. A deterministic
    /// measure of warm-start seed quality across a churn run.
    pub warm_seed_exact: u64,
    /// Component races won, per strategy label (fixed roster order).
    pub strategy_wins: Vec<(String, u64)>,
}

impl PortfolioStats {
    pub fn merge(&mut self, other: &PortfolioStats) {
        self.solves += other.solves;
        self.legacy_solves += other.legacy_solves;
        self.components += other.components;
        self.components_certified += other.components_certified;
        self.tasks_run += other.tasks_run;
        self.tasks_cancelled += other.tasks_cancelled;
        self.whole_model_wins += other.whole_model_wins;
        self.composite_wins += other.composite_wins;
        self.cache_hits += other.cache_hits;
        self.component_cache_hits += other.component_cache_hits;
        self.warm_starts += other.warm_starts;
        self.warm_seed_exact += other.warm_seed_exact;
        for (label, wins) in &other.strategy_wins {
            self.credit(label, *wins);
        }
    }

    /// Record every counter into a telemetry handle (one call per
    /// portfolio solve, from [`solve_portfolio_traced`]). Deterministic:
    /// every value is an output of the completed solve.
    pub fn record(&self, tel: &Telemetry) {
        if !tel.enabled() {
            return;
        }
        tel.add("portfolio_solves_total", "", self.solves);
        tel.add("portfolio_legacy_solves_total", "", self.legacy_solves);
        tel.add("portfolio_components_total", "", self.components);
        tel.add(
            "portfolio_components_certified_total",
            "",
            self.components_certified,
        );
        tel.add("portfolio_tasks_run_total", "", self.tasks_run);
        tel.add("portfolio_tasks_cancelled_total", "", self.tasks_cancelled);
        tel.add("portfolio_whole_model_wins_total", "", self.whole_model_wins);
        tel.add("portfolio_composite_wins_total", "", self.composite_wins);
        tel.add("portfolio_cache_hits_total", "", self.cache_hits);
        tel.add(
            "portfolio_component_cache_hits_total",
            "",
            self.component_cache_hits,
        );
        tel.add("portfolio_warm_starts_total", "", self.warm_starts);
        tel.add("portfolio_warm_seed_exact_total", "", self.warm_seed_exact);
        for (label, wins) in &self.strategy_wins {
            tel.add(
                "portfolio_strategy_wins_total",
                &format!("strategy=\"{label}\""),
                *wins,
            );
        }
    }

    fn credit(&mut self, label: &str, wins: u64) {
        for (l, w) in self.strategy_wins.iter_mut() {
            if l.as_str() == label {
                *w += wins;
                return;
            }
        }
        self.strategy_wins.push((label.to_string(), wins));
    }
}

/// Result of [`solve_portfolio`].
#[derive(Clone, Debug)]
pub struct PortfolioOutcome {
    pub solution: Solution,
    /// Per-component reports of this solve (empty on the legacy path).
    pub components: Vec<ComponentReport>,
    pub stats: PortfolioStats,
}

/// Maximise `objective` over `model` within `deadline`, using the
/// parallel portfolio when `cfg.threads > 1` and the single-threaded
/// solver otherwise.
pub fn solve_portfolio(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    solver: &SolverConfig,
    cfg: &PortfolioConfig,
) -> PortfolioOutcome {
    solve_portfolio_session(model, objective, deadline, solver, cfg, None)
}

/// [`solve_portfolio`] with an optional session certificate cache:
/// a previously *proven* solve of the same fingerprint replays without
/// invoking the solver; a miss solves (replaying clean decomposed
/// components, warm-starting the rest) and stores its certificate.
pub fn solve_portfolio_session(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    solver: &SolverConfig,
    cfg: &PortfolioConfig,
    session: Option<&mut SolveCache>,
) -> PortfolioOutcome {
    solve_portfolio_traced(
        model,
        objective,
        deadline,
        solver,
        cfg,
        session,
        &Telemetry::off(),
    )
}

/// [`solve_portfolio_session`] with a telemetry handle: spans cover the
/// cache lookup, decomposition, warm-start seeding, and the strategy
/// race (one lane per task); counters cover every [`PortfolioStats`]
/// field plus the winning task's search stats. Telemetry observes only
/// — the outcome is byte-identical to the untraced call.
pub fn solve_portfolio_traced(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    solver: &SolverConfig,
    cfg: &PortfolioConfig,
    session: Option<&mut SolveCache>,
    tel: &Telemetry,
) -> PortfolioOutcome {
    solve_portfolio_probed(
        model,
        objective,
        deadline,
        solver,
        cfg,
        session,
        tel,
        &Probe::off(),
    )
}

/// [`solve_portfolio_traced`] with a solve-forensics [`Probe`]. The
/// probe records only the **canonical exact lane** — the legacy solve at
/// one thread, the floor-detached whole-model anchor otherwise — so the
/// profile is byte-identical across thread counts on solves the deadline
/// does not truncate. At `threads > 1` on a single-component model the
/// armed probe inserts an extra anchor task whose result never reaches
/// the winner selection and is excluded from the merged search stats:
/// arming observes, it never changes the outcome. One caveat: a
/// warm-seeded session floors only the legacy lane, so cross-thread
/// profile identity is guaranteed for sessionless solves.
#[allow(clippy::too_many_arguments)]
pub fn solve_portfolio_probed(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    solver: &SolverConfig,
    cfg: &PortfolioConfig,
    mut session: Option<&mut SolveCache>,
    tel: &Telemetry,
    prof: &Probe,
) -> PortfolioOutcome {
    let fp = session
        .as_deref()
        .map(|_| fingerprint_solve(model, objective, solver, cfg));
    let hit = match (session.as_deref_mut(), fp) {
        (Some(cache), Some(fp)) => {
            let sp = tel.span("cache");
            let hit = cache.lookup_solve(fp);
            sp.arg("hit", hit.is_some());
            hit
        }
        _ => None,
    };
    let outcome = match hit {
        Some(hit) => replay_solve(hit),
        None if cfg.threads <= 1 => {
            solve_legacy(model, objective, deadline, solver, session, fp, tel, prof)
        }
        None => solve_parallel(model, objective, deadline, solver, cfg, session, fp, tel, prof),
    };
    outcome.stats.record(tel);
    outcome
}

/// Re-emit a cached proven solve as a fresh outcome. The replayed
/// solution carries empty search stats (nothing ran); `cache_hits`
/// marks the replay for the tier/churn reports.
fn replay_solve(hit: CachedSolve) -> PortfolioOutcome {
    PortfolioOutcome {
        solution: Solution {
            status: hit.status,
            objective: hit.objective,
            bound: hit.bound,
            values: hit.values,
            stats: SearchStats::default(),
        },
        components: hit.components,
        stats: PortfolioStats {
            cache_hits: 1,
            ..Default::default()
        },
    }
}

/// Project a model's warm-start hints onto a complete assignment and
/// return its objective value when that assignment is feasible — the
/// floor a session seeds into the race. The floor is some feasible
/// assignment's objective, hence never above the true optimum, so
/// strict pruning against it cannot change a completing solve's answer.
fn hint_floor(model: &Model, objective: &LinearExpr) -> Option<i64> {
    if model.num_vars() == 0 || model.hints.iter().all(Option::is_none) {
        return None;
    }
    let values: Vec<bool> = model.hints.iter().map(|h| *h == Some(true)).collect();
    model.feasible(&values).then(|| objective.eval(&values))
}

/// The single-threaded path, session-aware: seed the projected-hint
/// floor (pure acceleration) and store proven certificates. This *is*
/// the canonical exact lane — the probe records it under frame `exact`.
#[allow(clippy::too_many_arguments)]
fn solve_legacy(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    solver: &SolverConfig,
    session: Option<&mut SolveCache>,
    fp: Option<u64>,
    tel: &Telemetry,
    prof: &Probe,
) -> PortfolioOutcome {
    let mut stats = PortfolioStats {
        legacy_solves: 1,
        ..Default::default()
    };
    let solution = match session {
        None => {
            let _sp = tel.span("solve");
            let _pf = prof.frame("exact");
            let solution = solve_max_probed(model, objective, deadline, solver, None, prof);
            solution.stats.record(tel, "strategy=\"legacy\"");
            solution
        }
        Some(cache) => {
            let floor = {
                let _sp = tel.span("warm-start");
                hint_floor(model, objective)
            };
            let shared = floor.map(SharedIncumbent::seeded);
            if shared.is_some() {
                stats.warm_starts = 1;
                cache.stats.warm_seeds += 1;
            }
            let sp = tel.span("solve");
            sp.arg("warm", shared.is_some());
            let pf = prof.frame("exact");
            let solution =
                solve_max_probed(model, objective, deadline, solver, shared.as_ref(), prof);
            drop(pf);
            drop(sp);
            solution.stats.record(tel, "strategy=\"legacy\"");
            if solution.status.has_solution() && floor == Some(solution.objective) {
                stats.warm_seed_exact = 1;
            }
            if let (Some(fp), SolveStatus::Optimal | SolveStatus::Infeasible) =
                (fp, solution.status)
            {
                cache.store_solve(
                    fp,
                    CachedSolve {
                        status: solution.status,
                        objective: solution.objective,
                        bound: solution.bound,
                        values: solution.values.clone(),
                        components: Vec::new(),
                    },
                );
            }
            solution
        }
    };
    PortfolioOutcome {
        solution,
        components: Vec::new(),
        stats,
    }
}

#[allow(clippy::too_many_arguments)]
fn solve_parallel(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    solver: &SolverConfig,
    cfg: &PortfolioConfig,
    mut session: Option<&mut SolveCache>,
    fp: Option<u64>,
    tel: &Telemetry,
    prof: &Probe,
) -> PortfolioOutcome {
    let started = crate::telemetry::Stopwatch::start();
    let mut stats = PortfolioStats {
        solves: 1,
        ..Default::default()
    };

    // Cheap probe first: the common single-component case (plain paper
    // workloads, every lock-coupled phase-2 model) must not pay for
    // sub-model construction inside the solve window.
    let probe = {
        let sp = tel.span("decompose");
        let probe = cfg.decompose.then(|| decompose::probe(model));
        if let Some(p) = &probe {
            sp.arg("components", p.components);
        }
        probe
    };
    let (ncomp, constant_infeasible) = match &probe {
        Some(p) => (p.components, p.constant_infeasible),
        None => (usize::from(model.num_vars() > 0), false),
    };

    if constant_infeasible {
        let mut s = SearchStats::default();
        s.solve_time_s = started.elapsed_secs();
        return PortfolioOutcome {
            solution: Solution::infeasible(s),
            components: Vec::new(),
            stats,
        };
    }
    if ncomp == 0 {
        // Variable-free model: the solver answers trivially. Probed so
        // the trivial profile matches the `threads = 1` lane byte for
        // byte.
        let _pf = prof.frame("exact");
        return PortfolioOutcome {
            solution: solve_max_probed(model, objective, deadline, solver, None, prof),
            components: Vec::new(),
            stats,
        };
    }

    let roster = strategy::roster(solver, cfg.strategies);

    if ncomp == 1 {
        // Single component: race the strategies on the *original* model
        // references — no anchor, no sub-model clone. Rank 0 is the
        // exact single-threaded solve and wins all ties. An armed probe
        // inserts a canonical forensic lane: the exact solve at the
        // original seed (matching the `threads = 1` path), whose result
        // never reaches `pick_winner` (component `None`) and is skipped
        // when merging search stats — observation only.
        let probe_anchor = prof.enabled();
        let mut tasks: Vec<Task<'_>> =
            Vec::with_capacity(roster.len() + usize::from(probe_anchor));
        if probe_anchor {
            tasks.push(Task {
                component: None,
                rank: 0,
                label: "exact",
                model,
                objective,
                config: solver.clone(),
            });
        }
        tasks.extend(roster.iter().enumerate().map(|(rank, &(label, ref strat))| {
            let mut config = strat.clone();
            config.seed = strategy::task_seed(solver.seed, 0, rank);
            Task {
                component: Some(0),
                rank: rank as u32,
                label,
                model,
                objective,
                config,
            }
        }));
        let warm = session.as_deref().map(|_| {
            let _sp = tel.span("warm-start");
            WarmSeeds {
                whole: None,
                per_component: vec![hint_floor(model, objective)],
            }
        });
        if let (Some(w), Some(cache)) = (&warm, session.as_deref_mut()) {
            stats.warm_starts = w.count();
            cache.stats.warm_seeds += w.count();
        }
        let (mut results, cancelled) = {
            let sp = tel.span("strategy-race");
            sp.arg("tasks", tasks.len());
            run_race(&tasks, deadline, cfg.threads, warm.as_ref(), tel, prof)
        };
        stats.tasks_cancelled = cancelled;
        // The forensic anchor (slot 0 when armed) is not a racer: skip
        // it in `tasks_run` and the merged stats so `solve --json`
        // output is identical armed or off.
        let skip = usize::from(probe_anchor);
        stats.tasks_run = results.iter().skip(skip).filter(|r| r.is_some()).count() as u64;
        let mut merged_stats = SearchStats::default();
        for sol in results.iter().skip(skip).flatten() {
            merged_stats.merge(&sol.stats);
        }
        let (report, winner) = pick_winner(
            &tasks,
            &mut results,
            0,
            model.num_vars(),
            model.constraints.len(),
        );
        stats.components = 1;
        stats.components_certified = u64::from(report.status == SolveStatus::Optimal);
        if let Some(w) = &warm {
            if report.status.has_solution() && w.per_component[0] == Some(report.objective) {
                stats.warm_seed_exact = 1;
            }
        }
        let mut solution = match winner {
            Some(mut sol) => {
                stats.credit(report.winner, 1);
                sol.status = report.status;
                sol.bound = report.bound;
                sol
            }
            None if report.status == SolveStatus::Infeasible => {
                Solution::infeasible(SearchStats::default())
            }
            None => Solution::unknown(SearchStats::default(), report.bound),
        };
        if let (Some(cache), Some(fp)) = (session.as_deref_mut(), fp) {
            if matches!(solution.status, SolveStatus::Optimal | SolveStatus::Infeasible) {
                cache.store_solve(
                    fp,
                    CachedSolve {
                        status: solution.status,
                        objective: solution.objective,
                        bound: solution.bound,
                        values: solution.values.clone(),
                        components: vec![report.clone()],
                    },
                );
            }
        }
        merged_stats.solve_time_s = started.elapsed_secs();
        solution.stats = merged_stats;
        return PortfolioOutcome {
            solution,
            components: vec![report],
            stats,
        };
    }

    // ---- multi-component: full decomposition + fixed task list ------------
    // (the task list never depends on the worker count)
    let decomp = {
        let sp = tel.span("decompose");
        sp.arg("components", ncomp);
        decompose::decompose_probed(
            model,
            objective,
            probe.expect("ncomp > 1 implies the probe ran"),
        )
    };
    debug_assert_eq!(decomp.components.len(), ncomp);

    // Session replay: a component whose fingerprint matches a proven
    // cached result skips the race entirely (its certificate composes
    // like a freshly raced one); only dirty components get racer tasks.
    let mut comp_fps: Vec<Option<u64>> = vec![None; ncomp];
    let mut cached: Vec<Option<CachedComponent>> = (0..ncomp).map(|_| None).collect();
    if let Some(cache) = session.as_deref_mut() {
        for (c, comp) in decomp.components.iter().enumerate() {
            let cfp = fingerprint_solve(&comp.model, &comp.objective, solver, cfg);
            comp_fps[c] = Some(cfp);
            cached[c] = cache.lookup_component(cfp);
        }
    }
    stats.component_cache_hits = cached.iter().flatten().count() as u64;

    let mut tasks: Vec<Task<'_>> = Vec::with_capacity(1 + ncomp * roster.len());
    // Whole-model anchor: the exact single-threaded solve. Wins all
    // ties, which pins portfolio answers to the `threads = 1` path
    // whenever the deadline does not truncate it. It always runs — a
    // session replays components, never the anchor (its fingerprint is
    // the whole-solve entry, checked before decomposition).
    tasks.push(Task {
        component: None,
        rank: 0,
        label: WHOLE_MODEL,
        model,
        objective,
        config: solver.clone(),
    });
    for (c, comp) in decomp.components.iter().enumerate() {
        if cached[c].is_some() {
            continue; // replayed from the session cache — no racers
        }
        for (rank, &(label, ref strat)) in roster.iter().enumerate() {
            let mut config = strat.clone();
            config.seed = strategy::task_seed(solver.seed, c, rank);
            tasks.push(Task {
                component: Some(c),
                rank: rank as u32,
                label,
                model: &comp.model,
                objective: &comp.objective,
                config,
            });
        }
    }

    let warm = session.as_deref().map(|_| {
        let _sp = tel.span("warm-start");
        WarmSeeds {
            whole: hint_floor(model, objective),
            per_component: decomp
                .components
                .iter()
                .enumerate()
                .map(|(c, comp)| {
                    if cached[c].is_some() {
                        None
                    } else {
                        hint_floor(&comp.model, &comp.objective)
                    }
                })
                .collect(),
        }
    });
    if let (Some(w), Some(cache)) = (&warm, session.as_deref_mut()) {
        stats.warm_starts = w.count();
        cache.stats.warm_seeds += w.count();
    }

    let (mut results, cancelled) = {
        let sp = tel.span("strategy-race");
        sp.arg("tasks", tasks.len());
        run_race(&tasks, deadline, cfg.threads, warm.as_ref(), tel, prof)
    };
    stats.tasks_cancelled = cancelled;
    stats.tasks_run = results.iter().filter(|r| r.is_some()).count() as u64;

    let mut merged_stats = SearchStats::default();
    for sol in results.iter().flatten() {
        merged_stats.merge(&sol.stats);
    }

    // ---- per-component winners: objective, then lowest rank ---------------
    let mut component_reports: Vec<ComponentReport> = Vec::with_capacity(ncomp);
    let mut component_values: Vec<Option<Vec<bool>>> = Vec::with_capacity(ncomp);
    let mut any_infeasible = false;
    for (c, comp) in decomp.components.iter().enumerate() {
        if let Some(hit) = cached[c].take() {
            // Replayed certificate: proven Optimal (with values) or
            // proven Infeasible — anytime results are never cached.
            any_infeasible |= hit.report.status == SolveStatus::Infeasible;
            component_values.push(hit.report.status.has_solution().then_some(hit.values));
            component_reports.push(hit.report);
            continue;
        }
        let (report, winner) =
            pick_winner(&tasks, &mut results, c, comp.vars.len(), comp.cons.len());
        any_infeasible |= report.status == SolveStatus::Infeasible;
        if let Some(w) = &warm {
            if report.status.has_solution()
                && w.per_component.get(c).copied().flatten() == Some(report.objective)
            {
                stats.warm_seed_exact += 1;
            }
        }
        match winner {
            Some(sol) => {
                stats.credit(report.winner, 1);
                if report.status == SolveStatus::Optimal {
                    if let (Some(cache), Some(cfp)) = (session.as_deref_mut(), comp_fps[c]) {
                        cache.store_component(
                            cfp,
                            CachedComponent {
                                report: report.clone(),
                                values: sol.values.clone(),
                            },
                        );
                    }
                }
                component_values.push(Some(sol.values));
            }
            None => {
                if report.status == SolveStatus::Infeasible {
                    if let (Some(cache), Some(cfp)) = (session.as_deref_mut(), comp_fps[c]) {
                        cache.store_component(
                            cfp,
                            CachedComponent {
                                report: report.clone(),
                                values: Vec::new(),
                            },
                        );
                    }
                }
                component_values.push(None);
            }
        }
        component_reports.push(report);
    }
    stats.components = ncomp as u64;
    stats.components_certified = component_reports
        .iter()
        .filter(|r| r.status == SolveStatus::Optimal)
        .count() as u64;

    // ---- composite candidate: merge per-component winners ------------------
    let composite: Option<Solution> = if !any_infeasible
        && component_values.iter().all(Option::is_some)
    {
        let mut values = vec![false; model.num_vars()];
        for (c, local) in component_values.iter().enumerate() {
            decomp.scatter(c, local.as_ref().expect("checked above"), &mut values);
        }
        let objective_val: i64 = component_reports.iter().map(|r| r.objective).sum();
        debug_assert!(model.feasible(&values), "merged composite infeasible");
        let all_certified = component_reports
            .iter()
            .all(|r| r.status == SolveStatus::Optimal);
        let bound = component_reports
            .iter()
            .fold(0i64, |acc, r| acc.saturating_add(r.bound));
        Some(Solution {
            // The certificate composes: every component at its proven
            // optimum ⇒ the separable whole at its proven optimum.
            status: if all_certified {
                SolveStatus::Optimal
            } else {
                SolveStatus::Feasible
            },
            objective: objective_val,
            bound,
            values,
            stats: SearchStats::default(),
        })
    } else {
        None
    };

    // ---- final resolution: anchor vs composite, anchor wins ties -----------
    // The anchor always has a result here: it is task 0, cancellation
    // only ever targets same-component higher ranks, and a worker exists.
    let w = results[0].take().expect("anchor always runs");

    let mut solution = match composite {
        Some(comp) => {
            if comp.status.has_solution()
                && (!w.status.has_solution() || comp.objective > w.objective)
            {
                stats.composite_wins += 1;
                let mut comp = comp;
                comp.bound = comp.bound.min(if w.status == SolveStatus::Optimal {
                    w.objective
                } else {
                    w.bound
                });
                comp
            } else {
                stats.whole_model_wins += 1;
                let mut w = w;
                if w.status.has_solution() {
                    // A tied, fully certified composite proves the
                    // anchor's anytime answer optimal too.
                    if comp.status == SolveStatus::Optimal && comp.objective == w.objective {
                        w.status = SolveStatus::Optimal;
                    }
                    w.bound = w.bound.min(comp.bound);
                    if w.status == SolveStatus::Optimal {
                        w.bound = w.objective;
                    }
                }
                w
            }
        }
        None => {
            if any_infeasible && !w.status.has_solution() {
                // A component proved infeasibility the anchor's window
                // could not reach.
                Solution::infeasible(SearchStats::default())
            } else {
                if w.status.has_solution() {
                    stats.whole_model_wins += 1;
                }
                w
            }
        }
    };

    merged_stats.solve_time_s = started.elapsed_secs();
    solution.stats = merged_stats;
    if let (Some(cache), Some(fp)) = (session.as_deref_mut(), fp) {
        if matches!(solution.status, SolveStatus::Optimal | SolveStatus::Infeasible) {
            cache.store_solve(
                fp,
                CachedSolve {
                    status: solution.status,
                    objective: solution.objective,
                    bound: solution.bound,
                    values: solution.values.clone(),
                    components: component_reports.clone(),
                },
            );
        }
    }
    PortfolioOutcome {
        solution,
        components: component_reports,
        stats,
    }
}

/// Winner of one component's race: *(objective, then lowest rank)* over
/// the racers that ran — never wall-clock arrival. Returns the
/// component report plus the winning solution (taken out of `results`).
/// The report's certificate uses everything the race proved, not just
/// the winner: any racer's Optimal status certifies a tied winner, and
/// the bound is the tightest admissible bound any racer established.
fn pick_winner(
    tasks: &[Task<'_>],
    results: &mut [Option<Solution>],
    component: usize,
    vars: usize,
    cons: usize,
) -> (ComponentReport, Option<Solution>) {
    let mut winner: Option<(usize, i64, u32)> = None;
    let mut certified = false;
    let mut infeasible = false;
    let mut min_bound: Option<i64> = None;
    for (i, task) in tasks.iter().enumerate() {
        if task.component != Some(component) {
            continue;
        }
        let Some(sol) = &results[i] else { continue };
        min_bound = Some(min_bound.map_or(sol.bound, |b: i64| b.min(sol.bound)));
        match sol.status {
            SolveStatus::Infeasible => infeasible = true,
            SolveStatus::Optimal => certified = true,
            _ => {}
        }
        if sol.status.has_solution() {
            let better = match winner {
                None => true,
                Some((_, obj, rank)) => {
                    sol.objective > obj || (sol.objective == obj && task.rank < rank)
                }
            };
            if better {
                winner = Some((i, sol.objective, task.rank));
            }
        }
    }
    match winner {
        Some((wi, wobj, _)) => {
            let sol = results[wi].take().expect("winner result present");
            let report = ComponentReport {
                vars,
                cons,
                // Any racer's proof certifies every tied answer.
                status: if certified { SolveStatus::Optimal } else { sol.status },
                objective: wobj,
                bound: if certified {
                    wobj
                } else {
                    min_bound.expect("winner ran").min(sol.bound)
                },
                winner: tasks[wi].label,
            };
            (report, Some(sol))
        }
        None => (
            ComponentReport {
                vars,
                cons,
                status: if infeasible {
                    SolveStatus::Infeasible
                } else {
                    SolveStatus::Unknown
                },
                objective: 0,
                bound: min_bound.unwrap_or(0),
                winner: "-",
            },
            None,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::VarId;

    fn cfg(threads: usize) -> PortfolioConfig {
        PortfolioConfig {
            threads,
            decompose: true,
            strategies: 3,
        }
    }

    /// Figure-1 packing (3 pods, 2 nodes) — one component.
    fn figure1() -> (Model, LinearExpr) {
        let mut m = Model::new();
        let pods = [2048i64, 2048, 3072];
        let mut vars = Vec::new();
        for _ in &pods {
            let xs = m.new_vars(2);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            vars.push(xs);
        }
        for node in 0..2 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&pods).map(|(xs, &r)| (xs[node], r))),
                4096,
            );
        }
        let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
        (m, obj)
    }

    /// Two disjoint copies of a small packing — two components.
    fn two_pools() -> (Model, LinearExpr) {
        let mut m = Model::new();
        let mut obj = LinearExpr::new();
        for _pool in 0..2 {
            let pods = [600i64, 500, 400];
            let mut vars = Vec::new();
            for _ in &pods {
                let xs = m.new_vars(2);
                m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
                vars.push(xs);
            }
            for node in 0..2 {
                m.add_le(
                    LinearExpr::of(vars.iter().zip(&pods).map(|(xs, &r)| (xs[node], r))),
                    1000,
                );
            }
            for v in vars.iter().flatten() {
                obj.add(*v, 1);
            }
        }
        (m, obj)
    }

    #[test]
    fn threads_one_is_the_legacy_path() {
        let (m, obj) = figure1();
        let legacy = solve_max(&m, &obj, Deadline::unlimited(), &SolverConfig::default());
        let out = solve_portfolio(
            &m,
            &obj,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &cfg(1),
        );
        assert_eq!(out.solution.status, legacy.status);
        assert_eq!(out.solution.objective, legacy.objective);
        assert_eq!(out.solution.values, legacy.values);
        assert_eq!(out.stats.legacy_solves, 1);
        assert_eq!(out.stats.solves, 0);
        assert!(out.components.is_empty());
    }

    #[test]
    fn portfolio_matches_legacy_values_across_thread_counts() {
        for (m, obj) in [figure1(), two_pools()] {
            let legacy = solve_max(&m, &obj, Deadline::unlimited(), &SolverConfig::default());
            assert_eq!(legacy.status, SolveStatus::Optimal);
            for threads in [2usize, 4, 8] {
                let out = solve_portfolio(
                    &m,
                    &obj,
                    Deadline::unlimited(),
                    &SolverConfig::default(),
                    &cfg(threads),
                );
                assert_eq!(out.solution.status, SolveStatus::Optimal);
                assert_eq!(out.solution.objective, legacy.objective);
                assert_eq!(
                    out.solution.values, legacy.values,
                    "threads={threads} diverged from the single-threaded answer"
                );
                assert_eq!(out.solution.bound, out.solution.objective);
            }
        }
    }

    #[test]
    fn two_pools_decompose_and_certify() {
        let (m, obj) = two_pools();
        let out = solve_portfolio(
            &m,
            &obj,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &cfg(4),
        );
        assert_eq!(out.components.len(), 2);
        assert_eq!(out.stats.components, 2);
        assert_eq!(out.stats.components_certified, 2);
        assert_eq!(out.solution.status, SolveStatus::Optimal);
        assert!(m.feasible(&out.solution.values));
        // separable objective: the whole equals the sum of its parts
        assert_eq!(
            out.solution.objective,
            out.components.iter().map(|c| c.objective).sum::<i64>()
        );
    }

    #[test]
    fn constant_infeasibility_short_circuits() {
        let mut m = Model::new();
        let _x = m.new_var();
        m.add_ge(LinearExpr::new(), 1); // 0 >= 1
        let out = solve_portfolio(
            &m,
            &LinearExpr::new(),
            Deadline::unlimited(),
            &SolverConfig::default(),
            &cfg(2),
        );
        assert_eq!(out.solution.status, SolveStatus::Infeasible);
    }

    #[test]
    fn component_infeasibility_propagates() {
        let mut m = Model::new();
        let a = m.new_var(); // component 0: infeasible (a >= 1 and a <= 0)
        m.add_ge(LinearExpr::of([(a, 1)]), 1);
        m.add_le(LinearExpr::of([(a, 1)]), 0);
        let b = m.new_var(); // component 1: trivially fine
        m.add_le(LinearExpr::of([(b, 1)]), 1);
        let obj = LinearExpr::of([(a, 1), (b, 1)]);
        let out = solve_portfolio(
            &m,
            &obj,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &cfg(2),
        );
        assert_eq!(out.solution.status, SolveStatus::Infeasible);
    }

    #[test]
    fn empty_model_is_trivially_optimal() {
        let m = Model::new();
        let out = solve_portfolio(
            &m,
            &LinearExpr::new(),
            Deadline::unlimited(),
            &SolverConfig::default(),
            &cfg(4),
        );
        assert_eq!(out.solution.status, SolveStatus::Optimal);
        assert_eq!(out.solution.objective, 0);
    }

    #[test]
    fn no_decompose_still_races_strategies() {
        let (m, obj) = two_pools();
        let mut c = cfg(4);
        c.decompose = false;
        let out = solve_portfolio(&m, &obj, Deadline::unlimited(), &SolverConfig::default(), &c);
        assert_eq!(out.components.len(), 1, "presolve disabled: one blob");
        assert_eq!(out.solution.status, SolveStatus::Optimal);
        let with = solve_portfolio(
            &m,
            &obj,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &cfg(4),
        );
        assert_eq!(out.solution.objective, with.solution.objective);
    }

    #[test]
    fn armed_probe_never_changes_answers_and_profiles_identically() {
        // The forensic probe observes only: answers are byte-identical
        // armed vs off at every thread count, and the profile itself is
        // byte-identical across thread counts (canonical lane only).
        for (m, obj) in [figure1(), two_pools()] {
            let mut profiles = Vec::new();
            for threads in [1usize, 2, 8] {
                let off = solve_portfolio(
                    &m,
                    &obj,
                    Deadline::unlimited(),
                    &SolverConfig::default(),
                    &cfg(threads),
                );
                let prof = Probe::armed();
                let armed = solve_portfolio_probed(
                    &m,
                    &obj,
                    Deadline::unlimited(),
                    &SolverConfig::default(),
                    &cfg(threads),
                    None,
                    &Telemetry::off(),
                    &prof,
                );
                assert_eq!(armed.solution.status, off.solution.status);
                assert_eq!(armed.solution.objective, off.solution.objective);
                assert_eq!(armed.solution.values, off.solution.values);
                assert_eq!(armed.solution.bound, off.solution.bound);
                profiles.push(prof.export_profile_json());
            }
            assert_eq!(profiles[0], profiles[1], "threads 1 vs 2 profile");
            assert_eq!(profiles[1], profiles[2], "threads 2 vs 8 profile");
            assert!(profiles[0].contains("exact"), "canonical lane recorded");
        }
    }

    #[test]
    fn stats_merge_accumulates_strategy_wins() {
        let mut a = PortfolioStats::default();
        a.credit("default", 2);
        let mut b = PortfolioStats {
            solves: 1,
            components: 3,
            ..Default::default()
        };
        b.credit("default", 1);
        b.credit("lns-heavy", 4);
        a.merge(&b);
        assert_eq!(a.solves, 1);
        assert_eq!(a.components, 3);
        assert_eq!(
            a.strategy_wins,
            vec![("default".to_string(), 3), ("lns-heavy".to_string(), 4)]
        );
    }

    #[test]
    fn session_cache_replays_proven_solves() {
        let (m, obj) = figure1();
        let solver = SolverConfig::default();
        let mut cache = SolveCache::new();
        let first = solve_portfolio_session(
            &m,
            &obj,
            Deadline::unlimited(),
            &solver,
            &cfg(1),
            Some(&mut cache),
        );
        assert_eq!(first.solution.status, SolveStatus::Optimal);
        assert_eq!(first.stats.cache_hits, 0);
        assert_eq!(cache.stats.stored_solves, 1);
        // cold parity: the session path is the plain path plus caching
        let plain = solve_portfolio(&m, &obj, Deadline::unlimited(), &solver, &cfg(1));
        assert_eq!(first.solution.values, plain.solution.values);

        let replay = solve_portfolio_session(
            &m,
            &obj,
            Deadline::unlimited(),
            &solver,
            &cfg(1),
            Some(&mut cache),
        );
        assert_eq!(replay.stats.cache_hits, 1);
        assert_eq!(replay.stats.legacy_solves, 0, "no solver invocation");
        assert_eq!(replay.solution.status, SolveStatus::Optimal);
        assert_eq!(replay.solution.values, first.solution.values);
        assert_eq!(replay.solution.objective, first.solution.objective);

        // the cache key is thread-independent: an 8-worker re-solve of
        // the same model replays the same certificate
        let replay8 = solve_portfolio_session(
            &m,
            &obj,
            Deadline::unlimited(),
            &solver,
            &cfg(8),
            Some(&mut cache),
        );
        assert_eq!(replay8.stats.cache_hits, 1);
        assert_eq!(replay8.solution.values, first.solution.values);
        assert_eq!(cache.stats.solve_hits, 2);
    }

    #[test]
    fn session_replays_clean_components_and_warm_starts_dirty_ones() {
        let (m, obj) = two_pools();
        let solver = SolverConfig::default();
        let mut cache = SolveCache::new();
        let cold = solve_portfolio_session(
            &m,
            &obj,
            Deadline::unlimited(),
            &solver,
            &cfg(4),
            Some(&mut cache),
        );
        assert_eq!(cold.solution.status, SolveStatus::Optimal);
        assert_eq!(cold.stats.component_cache_hits, 0);
        assert_eq!(cache.stats.stored_components, 2, "both pools certified");

        // Dirty pool 1 only (a fresh hint changes its fingerprint and
        // the whole-model fingerprint; pool 0 is untouched).
        let mut m2 = m.clone();
        m2.hint(VarId(6), true); // pool 1's first variable
        let warm = solve_portfolio_session(
            &m2,
            &obj,
            Deadline::unlimited(),
            &solver,
            &cfg(4),
            Some(&mut cache),
        );
        assert_eq!(warm.stats.cache_hits, 0, "whole model is dirty");
        assert_eq!(warm.stats.component_cache_hits, 1, "pool 0 replayed");
        assert!(warm.stats.warm_starts >= 1, "dirty work seeded a floor");

        // Byte-identity with a cold (sessionless) solve of the same model.
        let coldref = solve_portfolio(&m2, &obj, Deadline::unlimited(), &solver, &cfg(4));
        assert_eq!(warm.solution.status, coldref.solution.status);
        assert_eq!(warm.solution.objective, coldref.solution.objective);
        assert_eq!(warm.solution.values, coldref.solution.values);
    }

    #[test]
    fn hints_survive_decomposition_into_the_race() {
        // A warm-start hint placed on one pool must steer that pool's
        // winner exactly as it steers the whole-model solve.
        let (mut m, obj) = two_pools();
        m.hint(VarId(1), true); // pod 0 of pool 0 -> node 1
        let legacy = solve_max(&m, &obj, Deadline::unlimited(), &SolverConfig::default());
        let out = solve_portfolio(
            &m,
            &obj,
            Deadline::unlimited(),
            &SolverConfig::default(),
            &cfg(8),
        );
        assert_eq!(out.solution.values, legacy.values);
    }
}
