//! The portfolio's strategy roster: diverse solver configurations raced
//! over every component under one shared deadline.
//!
//! Rank order is part of the determinism contract — ties on objective
//! resolve to the **lowest rank**, and rank 0 is the caller's base
//! configuration *unchanged* (same seed, same toggles). Whenever rank 0
//! runs to completion it proves the component optimum, ties every rival,
//! and wins the tie-break — which is exactly what keeps portfolio
//! answers aligned bit-for-bit with the single-threaded solver on
//! instances the deadline does not truncate.

use crate::solver::SolverConfig;
use crate::util::rng::splitmix64;

/// Largest roster [`roster`] will build.
pub const MAX_STRATEGIES: usize = 4;

/// Strategy labels in fixed rank order.
pub const STRATEGY_NAMES: [&str; MAX_STRATEGIES] =
    ["default", "greedy-warm", "lns-heavy", "easiest-first"];

/// Build the roster of `count` strategies (clamped to
/// `1..=MAX_STRATEGIES`) from the caller's base configuration.
pub fn roster(base: &SolverConfig, count: usize) -> Vec<(&'static str, SolverConfig)> {
    let count = count.clamp(1, MAX_STRATEGIES);
    let mut out = Vec::with_capacity(count);
    // Rank 0: the base configuration, untouched (see module docs).
    out.push((STRATEGY_NAMES[0], base.clone()));
    if count > 1 {
        // Hint-first descent: reproduce the warm start (the default
        // scheduler's placement / the previous tier's plan) immediately
        // and improve from there — the best time-to-first-incumbent on
        // fragmented states.
        out.push((
            STRATEGY_NAMES[1],
            SolverConfig {
                use_best_fit: false,
                use_lns: false,
                ..base.clone()
            },
        ));
    }
    if count > 2 {
        // Anytime-focused: most of the window goes to ruin-and-recreate
        // polish instead of exhaustive proof.
        out.push((
            STRATEGY_NAMES[2],
            SolverConfig {
                use_lns: true,
                lns_fraction: 0.6,
                ..base.clone()
            },
        ));
    }
    if count > 3 {
        // Complementary branching order (easiest group first).
        out.push((
            STRATEGY_NAMES[3],
            SolverConfig {
                branch_easiest_first: true,
                ..base.clone()
            },
        ));
    }
    out
}

/// Per-(component, rank) seed: a pure function of the base seed so runs
/// replay exactly, with rank 0 left untouched (bit-compat with the
/// single-threaded solver).
pub fn task_seed(base: u64, component: usize, rank: usize) -> u64 {
    if rank == 0 {
        base
    } else {
        let salt = (((component as u64) << 8) | rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut s = base ^ salt;
        splitmix64(&mut s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_zero_is_the_base_config_untouched() {
        let mut base = SolverConfig::default();
        base.seed = 0xABCD;
        base.use_symmetry = false;
        let r = roster(&base, 4);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].0, "default");
        assert_eq!(r[0].1.seed, 0xABCD);
        assert!(!r[0].1.use_symmetry);
        // diversification knobs differ from the base
        assert!(!r[1].1.use_best_fit);
        assert!(r[2].1.lns_fraction > base.lns_fraction);
        assert!(r[3].1.branch_easiest_first);
    }

    #[test]
    fn roster_size_clamped() {
        let base = SolverConfig::default();
        assert_eq!(roster(&base, 0).len(), 1);
        assert_eq!(roster(&base, 99).len(), MAX_STRATEGIES);
    }

    #[test]
    fn task_seeds_replay_and_diversify() {
        assert_eq!(task_seed(7, 3, 0), 7, "rank 0 keeps the base seed");
        assert_eq!(task_seed(7, 3, 2), task_seed(7, 3, 2));
        assert_ne!(task_seed(7, 3, 2), task_seed(7, 3, 1));
        assert_ne!(task_seed(7, 3, 2), task_seed(7, 4, 2));
    }
}
