//! Structure detection: group variables under at-most-one constraints.
//!
//! The optimiser's models are assignment-shaped: for every pod there is a
//! constraint `Σ_j x_{i,j} ≤ 1` over its candidate nodes. Branching on a
//! whole *group* (pick one option or none) is exponentially stronger than
//! branching single booleans — it never explores the vacuous
//! "x_{i,j}=false for one j, undecided elsewhere" frontier.
//!
//! Variables not covered by any at-most-one constraint become singleton
//! groups, so the search remains complete for arbitrary models.

use super::model::{CmpOp, Model, VarId};
use super::probe::Probe;

/// A branchable group: choose at most one of `options` to set true.
#[derive(Clone, Debug, PartialEq)]
pub struct Group {
    pub options: Vec<VarId>,
}

/// Partition of all model variables into groups.
#[derive(Clone, Debug)]
pub struct Structure {
    pub groups: Vec<Group>,
    /// var -> owning group index.
    pub var_group: Vec<u32>,
}

/// Detect groups. A constraint qualifies iff it is `Σ x ≤ 1` with all
/// coefficients exactly 1 and at least 2 variables; each variable joins
/// at most one group (first qualifying constraint wins).
pub fn detect_structure(model: &Model) -> Structure {
    let nv = model.num_vars();
    let mut var_group = vec![u32::MAX; nv];
    let mut groups: Vec<Group> = Vec::new();

    for c in &model.constraints {
        if c.op != CmpOp::Le || c.rhs != 1 || c.expr.terms.len() < 2 {
            continue;
        }
        if !c.expr.terms.iter().all(|&(_, coef)| coef == 1) {
            continue;
        }
        if c.expr.terms.iter().any(|&(v, _)| var_group[v.idx()] != u32::MAX) {
            continue; // overlapping groups not supported: keep the first
        }
        let gi = groups.len() as u32;
        let options: Vec<VarId> = c.expr.terms.iter().map(|&(v, _)| v).collect();
        for &v in &options {
            var_group[v.idx()] = gi;
        }
        groups.push(Group { options });
    }

    // Singleton groups for everything uncovered.
    for v in 0..nv {
        if var_group[v] == u32::MAX {
            var_group[v] = groups.len() as u32;
            groups.push(Group {
                options: vec![VarId(v as u32)],
            });
        }
    }

    Structure { groups, var_group }
}

/// [`detect_structure`] plus solve forensics: records how presolve
/// carved the model — branchable multi-option groups versus singleton
/// fallbacks — so a profile shows whether group branching (the engine's
/// main structural lever) engaged at all.
pub fn detect_structure_probed(model: &Model, probe: &Probe) -> Structure {
    let s = detect_structure(model);
    if probe.enabled() {
        let singletons = s.groups.iter().filter(|g| g.options.len() == 1).count() as u64;
        probe.attr("search:presolve", "groups", s.groups.len() as u64 - singletons);
        probe.attr("search:presolve", "singletons", singletons);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::model::LinearExpr;

    #[test]
    fn detects_assignment_groups() {
        let mut m = Model::new();
        let xs = m.new_vars(4); // pod A options
        let ys = m.new_vars(4); // pod B options
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        m.add_le(LinearExpr::of(ys.iter().map(|&v| (v, 1))), 1);
        // a capacity constraint should not create a group
        m.add_le(LinearExpr::of([(xs[0], 500), (ys[0], 600)]), 1000);
        let s = detect_structure(&m);
        assert_eq!(s.groups.len(), 2);
        assert_eq!(s.groups[0].options, xs);
        assert_eq!(s.groups[1].options, ys);
        assert_eq!(s.var_group[xs[1].idx()], 0);
        assert_eq!(s.var_group[ys[3].idx()], 1);
    }

    #[test]
    fn uncovered_vars_become_singletons() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        m.add_le(LinearExpr::of([(a, 2), (b, 1)]), 2); // coef 2: not a group
        let s = detect_structure(&m);
        assert_eq!(s.groups.len(), 2);
        assert_eq!(s.groups[0].options, vec![a]);
        assert_eq!(s.groups[1].options, vec![b]);
    }

    #[test]
    fn overlapping_amo_keeps_first() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        let c = m.new_var();
        m.add_le(LinearExpr::of([(a, 1), (b, 1)]), 1);
        m.add_le(LinearExpr::of([(b, 1), (c, 1)]), 1); // overlaps on b
        let s = detect_structure(&m);
        assert_eq!(s.groups[0].options, vec![a, b]);
        // c fell back to a singleton
        assert!(s.groups.iter().any(|g| g.options == vec![c]));
    }

    #[test]
    fn probed_detection_counts_groups_and_singletons() {
        let mut m = Model::new();
        let xs = m.new_vars(3);
        let y = m.new_var();
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        m.add_le(LinearExpr::of([(xs[0], 2), (y, 1)]), 2);
        let probe = Probe::armed();
        let s = detect_structure_probed(&m, &probe);
        assert_eq!(s.groups.len(), 2); // one real group + y singleton
        let eff = probe.module_effort();
        assert!(eff.contains(&("search:presolve".to_string(), "groups", 1)));
        assert!(eff.contains(&("search:presolve".to_string(), "singletons", 1)));
        // Off probe: same structure, nothing recorded.
        let off = Probe::off();
        let s2 = detect_structure_probed(&m, &off);
        assert_eq!(s2.groups.len(), s.groups.len());
        assert!(off.module_effort().is_empty());
    }

    #[test]
    fn rhs_greater_than_one_not_grouped() {
        let mut m = Model::new();
        let xs = m.new_vars(3);
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 2);
        let s = detect_structure(&m);
        assert_eq!(s.groups.len(), 3); // all singletons
    }
}
