//! Bounds-consistency propagation over linear constraints with a trail.
//!
//! For every constraint `Σ c_i·x_i op b` the propagator maintains three
//! incremental sums: `fixed` (contribution of variables fixed true),
//! `pos_open` / `neg_open` (total positive / negative coefficient mass
//! still unfixed). From those, the reachable activity interval is
//!
//! ```text
//! [fixed + neg_open,  fixed + pos_open]
//! ```
//!
//! and the standard filtering rules apply: an empty intersection with
//! the feasible side of `op b` is a conflict; a variable whose value
//! would force emptiness is fixed to the opposite value. Assignments are
//! recorded on a trail with level marks for chronological backtracking.

use super::model::{CmpOp, Model, VarId};

const UNKNOWN: i8 = 0;
const TRUE: i8 = 1;
const FALSE: i8 = -1;

/// Trail-based propagation engine. Borrowed by the search for the
/// duration of one solve.
pub struct Propagator {
    /// Per-variable value: 0 unknown, 1 true, -1 false.
    values: Vec<i8>,
    /// Assigned variables in order.
    trail: Vec<u32>,
    /// Stack of trail lengths at each decision level.
    trail_lim: Vec<usize>,
    /// Per-constraint incremental sums.
    fixed: Vec<i64>,
    pos_open: Vec<i64>,
    neg_open: Vec<i64>,
    /// var -> [(constraint index, coefficient)]
    occurs: Vec<Vec<(u32, i64)>>,
    /// Constraint terms, flattened copies for cache-friendly scans.
    cons_terms: Vec<Vec<(u32, i64)>>,
    cons_op: Vec<CmpOp>,
    cons_rhs: Vec<i64>,
    /// Largest |coefficient| per constraint (static). Lets the
    /// propagator skip the O(terms) filtering scan when no variable
    /// could possibly be forced — the top hot-path optimisation
    /// (EXPERIMENTS.md §Perf: propagate_queue was 68% of solve time).
    cons_max_abs: Vec<i64>,
    /// Queue-membership flags: dedup wakes (one scan per wave instead of
    /// one per assigned variable).
    on_queue: Vec<bool>,
    /// Reusable wave queue (avoids a malloc per decision — §Perf #3).
    scratch: Vec<u32>,
    /// Number of propagations performed (stats).
    pub propagations: u64,
    /// Per-constraint propagation counts (solve forensics). `None`
    /// unless built via [`new_probed`](Self::new_probed) with the probe
    /// armed — the off path pays one predictable branch, no allocation.
    per_cons: Option<Vec<u64>>,
    /// Constraint index behind the most recent conflict (forensics).
    /// Cleared at each `decide`; `None` when the conflict had no
    /// constraint (e.g. an assignment contradicting the trail).
    last_conflict: Option<u32>,
}

impl Propagator {
    /// Build from a model and run root propagation. `None` = infeasible
    /// at the root.
    pub fn new(model: &Model) -> Option<Propagator> {
        Self::new_probed(model, false)
    }

    /// Like [`new`](Self::new), but when `probed` also records
    /// per-constraint propagation counts (including the root wave, which
    /// runs after the counters are armed) and conflict attribution for
    /// the solve-forensics profiler.
    pub fn new_probed(model: &Model, probed: bool) -> Option<Propagator> {
        let nv = model.num_vars();
        let nc = model.constraints.len();
        let mut occurs: Vec<Vec<(u32, i64)>> = vec![Vec::new(); nv];
        let mut cons_terms = Vec::with_capacity(nc);
        let mut pos_open = vec![0i64; nc];
        let mut neg_open = vec![0i64; nc];
        for (ci, c) in model.constraints.iter().enumerate() {
            let mut terms = Vec::with_capacity(c.expr.terms.len());
            for &(v, coef) in &c.expr.terms {
                occurs[v.idx()].push((ci as u32, coef));
                terms.push((v.0, coef));
                if coef > 0 {
                    pos_open[ci] += coef;
                } else {
                    neg_open[ci] += coef;
                }
            }
            // Descending |coef| order lets the filtering scan stop at the
            // first term below the forcing threshold (§Perf change #2).
            terms.sort_by_key(|&(_, k)| std::cmp::Reverse(k.abs()));
            cons_terms.push(terms);
        }
        let cons_max_abs = model
            .constraints
            .iter()
            .map(|c| c.expr.terms.iter().map(|&(_, k)| k.abs()).max().unwrap_or(0))
            .collect();
        let mut p = Propagator {
            values: vec![UNKNOWN; nv],
            trail: Vec::with_capacity(nv),
            trail_lim: Vec::new(),
            fixed: vec![0; nc],
            pos_open,
            neg_open,
            occurs,
            cons_terms,
            cons_op: model.constraints.iter().map(|c| c.op).collect(),
            cons_rhs: model.constraints.iter().map(|c| c.rhs).collect(),
            cons_max_abs,
            on_queue: vec![false; nc],
            scratch: Vec::with_capacity(nc),
            propagations: 0,
            per_cons: if probed { Some(vec![0; nc]) } else { None },
            last_conflict: None,
        };
        // Root propagation over all constraints.
        p.on_queue.iter_mut().for_each(|f| *f = true);
        let mut all: Vec<u32> = (0..nc as u32).collect();
        if p.propagate_queue(&mut all) {
            Some(p)
        } else {
            None
        }
    }

    #[inline]
    pub fn value(&self, v: VarId) -> Option<bool> {
        match self.values[v.idx()] {
            TRUE => Some(true),
            FALSE => Some(false),
            _ => None,
        }
    }

    #[inline]
    pub fn is_unknown(&self, v: VarId) -> bool {
        self.values[v.idx()] == UNKNOWN
    }

    pub fn num_assigned(&self) -> usize {
        self.trail.len()
    }

    /// Open a new decision level.
    pub fn push_level(&mut self) {
        self.trail_lim.push(self.trail.len());
    }

    /// Undo to the previous decision level.
    pub fn pop_level(&mut self) {
        let mark = self.trail_lim.pop().expect("pop without push");
        while self.trail.len() > mark {
            let v = self.trail.pop().unwrap() as usize;
            let was_true = self.values[v] == TRUE;
            self.values[v] = UNKNOWN;
            for &(ci, coef) in &self.occurs[v] {
                let ci = ci as usize;
                if was_true {
                    self.fixed[ci] -= coef;
                }
                if coef > 0 {
                    self.pos_open[ci] += coef;
                } else {
                    self.neg_open[ci] += coef;
                }
            }
        }
    }

    pub fn level(&self) -> usize {
        self.trail_lim.len()
    }

    /// Assign `v := val` and propagate to fixpoint. Returns `false` on
    /// conflict (caller must `pop_level`).
    pub fn decide(&mut self, v: VarId, val: bool) -> bool {
        self.last_conflict = None;
        let mut queue = std::mem::take(&mut self.scratch);
        queue.clear();
        if !self.enqueue_assign(v, val, &mut queue) {
            self.scratch = queue;
            return false;
        }
        let ok = self.propagate_queue(&mut queue);
        self.scratch = queue;
        ok
    }

    /// Record an assignment and collect affected constraints.
    fn enqueue_assign(&mut self, v: VarId, val: bool, queue: &mut Vec<u32>) -> bool {
        match self.values[v.idx()] {
            TRUE => return val,
            FALSE => return !val,
            _ => {}
        }
        self.values[v.idx()] = if val { TRUE } else { FALSE };
        self.trail.push(v.0);
        for i in 0..self.occurs[v.idx()].len() {
            let (ci, coef) = self.occurs[v.idx()][i];
            let c = ci as usize;
            if val {
                self.fixed[c] += coef;
            }
            if coef > 0 {
                self.pos_open[c] -= coef;
            } else {
                self.neg_open[c] -= coef;
            }
            if !self.on_queue[c] {
                self.on_queue[c] = true;
                queue.push(ci);
            }
        }
        true
    }

    /// Work through the constraint queue until fixpoint or conflict.
    /// On conflict, clears all queue-membership flags (the aborted
    /// wave's entries would otherwise suppress future wakes).
    fn propagate_queue(&mut self, queue: &mut Vec<u32>) -> bool {
        let ok = self.propagate_queue_inner(queue);
        if !ok {
            self.on_queue.iter_mut().for_each(|f| *f = false);
        }
        ok
    }

    fn propagate_queue_inner(&mut self, queue: &mut Vec<u32>) -> bool {
        while let Some(ci) = queue.pop() {
            self.propagations += 1;
            let c = ci as usize;
            if let Some(pc) = &mut self.per_cons {
                pc[c] += 1;
            }
            self.on_queue[c] = false;
            let rhs = self.cons_rhs[c];
            let min = self.fixed[c] + self.neg_open[c];
            let max = self.fixed[c] + self.pos_open[c];
            let op = self.cons_op[c];

            let check_le = matches!(op, CmpOp::Le | CmpOp::Eq);
            let check_ge = matches!(op, CmpOp::Ge | CmpOp::Eq);

            if check_le && min > rhs {
                self.last_conflict = Some(ci);
                return false;
            }
            if check_ge && max < rhs {
                self.last_conflict = Some(ci);
                return false;
            }

            // Skip the O(terms) scan when no variable can be forced:
            // forcing requires min + |coef| > rhs (≤ side) or
            // max - |coef| < rhs (≥ side) for some open var; bound the
            // |coef| by the constraint's static maximum.
            let m = self.cons_max_abs[c];
            let may_force_le = check_le && min + m > rhs;
            let may_force_ge = check_ge && max - m < rhs;
            if !may_force_le && !may_force_ge {
                continue;
            }

            // Forcing threshold: a variable can only be forced when
            // |coef| exceeds the slack on some active side. Terms are
            // sorted by |coef| descending, so the scan breaks early.
            let thr = {
                let t_le = if check_le { rhs - min } else { i64::MAX };
                let t_ge = if check_ge { max - rhs } else { i64::MAX };
                t_le.min(t_ge)
            };

            // Filter unfixed variables of this constraint.
            // (Index-based loop: enqueue_assign mutates self.)
            for ti in 0..self.cons_terms[c].len() {
                let (v, coef) = self.cons_terms[c][ti];
                if coef.abs() <= thr {
                    break; // nothing below can force either side
                }
                if self.values[v as usize] != UNKNOWN {
                    continue;
                }
                let var = VarId(v);
                if check_le {
                    if coef > 0 && min + coef > rhs {
                        if !self.enqueue_assign(var, false, queue) {
                            self.last_conflict = Some(ci);
                            return false;
                        }
                        continue;
                    }
                    if coef < 0 && min - coef > rhs {
                        if !self.enqueue_assign(var, true, queue) {
                            self.last_conflict = Some(ci);
                            return false;
                        }
                        continue;
                    }
                }
                if check_ge {
                    if coef > 0 && max - coef < rhs {
                        if !self.enqueue_assign(var, true, queue) {
                            self.last_conflict = Some(ci);
                            return false;
                        }
                        continue;
                    }
                    if coef < 0 && max + coef < rhs {
                        if !self.enqueue_assign(var, false, queue) {
                            self.last_conflict = Some(ci);
                            return false;
                        }
                    }
                }
            }
        }
        true
    }

    // ---- introspection for the search layer -------------------------------

    /// Current fixed-true contribution of constraint `ci`.
    #[inline]
    pub fn cons_fixed(&self, ci: usize) -> i64 {
        self.fixed[ci]
    }

    /// Total number of trail entries (assigned vars).
    #[inline]
    pub fn trail_len(&self) -> usize {
        self.trail.len()
    }

    /// Trail entries `[from..]` — the vars assigned since a caller-held
    /// mark. Used by the search to incrementally maintain objective
    /// bookkeeping.
    #[inline]
    pub fn trail_since(&self, from: usize) -> &[u32] {
        &self.trail[from..]
    }

    /// Constraint behind the most recent conflict, if any was recorded
    /// (solve forensics — valid until the next `decide`).
    #[inline]
    pub fn last_conflict(&self) -> Option<usize> {
        self.last_conflict.map(|ci| ci as usize)
    }

    /// Per-constraint propagation counts (`None` unless probed).
    pub fn per_cons_propagations(&self) -> Option<&[u64]> {
        self.per_cons.as_deref()
    }

    /// Snapshot the current (possibly partial) assignment as booleans,
    /// unknowns defaulting to `false` (safe for pure-≤ models; the
    /// search only calls this when all groups are decided).
    pub fn snapshot(&self) -> Vec<bool> {
        self.values.iter().map(|&v| v == TRUE).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::model::LinearExpr;

    #[test]
    fn at_most_one_propagates_exclusion() {
        let mut m = Model::new();
        let xs = m.new_vars(3);
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        let mut p = Propagator::new(&m).unwrap();
        p.push_level();
        assert!(p.decide(xs[0], true));
        assert_eq!(p.value(xs[1]), Some(false)); // forced by ≤1
        assert_eq!(p.value(xs[2]), Some(false));
        p.pop_level();
        assert!(p.is_unknown(xs[1]));
    }

    #[test]
    fn capacity_constraint_excludes_oversize() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        // 700a + 600b <= 1000: both true impossible
        m.add_le(LinearExpr::of([(a, 700), (b, 600)]), 1000);
        let mut p = Propagator::new(&m).unwrap();
        p.push_level();
        assert!(p.decide(a, true));
        assert_eq!(p.value(b), Some(false));
    }

    #[test]
    fn ge_forces_inclusion() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        m.add_ge(LinearExpr::of([(a, 1), (b, 1)]), 2); // both must be true
        let p = Propagator::new(&m).unwrap();
        assert_eq!(p.value(a), Some(true));
        assert_eq!(p.value(b), Some(true));
    }

    #[test]
    fn eq_conflict_detected() {
        let mut m = Model::new();
        let a = m.new_var();
        m.add_eq(LinearExpr::of([(a, 1)]), 1);
        m.add_eq(LinearExpr::of([(a, 1)]), 0);
        assert!(Propagator::new(&m).is_none()); // root infeasible
    }

    #[test]
    fn negative_coefficients() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        // a - b <= 0  ⇒  a ⇒ b
        m.add_le(LinearExpr::of([(a, 1), (b, -1)]), 0);
        let mut p = Propagator::new(&m).unwrap();
        p.push_level();
        assert!(p.decide(a, true));
        assert_eq!(p.value(b), Some(true));
        p.pop_level();
        // ¬b ⇒ ¬a
        p.push_level();
        assert!(p.decide(b, false));
        assert_eq!(p.value(a), Some(false));
    }

    #[test]
    fn conflict_on_decide_returns_false() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        m.add_ge(LinearExpr::of([(a, 1), (b, 1)]), 1);
        let mut p = Propagator::new(&m).unwrap();
        p.push_level();
        assert!(p.decide(a, false)); // ok: forces b
        assert_eq!(p.value(b), Some(true));
        p.pop_level();
        p.push_level();
        assert!(p.decide(a, false));
        assert!(!p.decide(b, false)); // both false violates ≥1
    }

    #[test]
    fn probed_counts_and_conflict_attribution() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        m.add_le(LinearExpr::of([(a, 1), (b, 1)]), 1); // ci 0
        m.add_ge(LinearExpr::of([(a, 1), (b, 1)]), 1); // ci 1
        let mut p = Propagator::new_probed(&m, true).unwrap();
        // Root wave counted per constraint.
        let pc = p.per_cons_propagations().unwrap();
        assert_eq!(pc.len(), 2);
        assert!(pc.iter().sum::<u64>() >= 2);
        assert_eq!(pc.iter().sum::<u64>(), p.propagations);
        p.push_level();
        assert!(p.decide(a, false));
        assert_eq!(p.value(b), Some(true)); // ≥1 forces b
        p.pop_level();
        p.push_level();
        assert!(p.decide(a, true)); // ≤1 forces ¬b
        assert!(!p.decide(b, true)); // contradicts trail: no constraint
        assert_eq!(p.last_conflict(), None);
        p.pop_level();
        // A propagation-detected conflict names its constraint.
        let mut m2 = Model::new();
        let x = m2.new_var();
        let y = m2.new_var();
        m2.add_le(LinearExpr::of([(x, 1), (y, 1)]), 1); // ci 0
        m2.add_ge(LinearExpr::of([(x, 1), (y, 1)]), 2); // ci 1: needs both
        // ≥2 forces both true at the root, then ≤1 conflicts: root-infeasible.
        assert!(Propagator::new_probed(&m2, true).is_none());
        let mut m3 = Model::new();
        let u = m3.new_var();
        let v = m3.new_var();
        let w = m3.new_var();
        m3.add_le(LinearExpr::of([(u, 1), (v, 1), (w, 1)]), 1); // ci 0
        m3.add_ge(LinearExpr::of([(v, 1), (w, 1)]), 1); // ci 1
        let mut q = Propagator::new_probed(&m3, true).unwrap();
        q.push_level();
        // u true: ≤1 forces ¬v, ¬w, which violates ci 1.
        assert!(!q.decide(u, true));
        assert!(q.last_conflict().is_some());
        // Unprobed propagator allocates no per-constraint counters.
        let plain = Propagator::new(&m3).unwrap();
        assert!(plain.per_cons_propagations().is_none());
    }

    #[test]
    fn trail_restores_across_multiple_levels() {
        let mut m = Model::new();
        let xs = m.new_vars(4);
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 2);
        let mut p = Propagator::new(&m).unwrap();
        p.push_level();
        assert!(p.decide(xs[0], true));
        p.push_level();
        assert!(p.decide(xs[1], true));
        // two trues: remaining forced false
        assert_eq!(p.value(xs[2]), Some(false));
        p.pop_level();
        assert!(p.is_unknown(xs[2]));
        p.pop_level();
        assert!(p.is_unknown(xs[1]));
        assert_eq!(p.num_assigned(), 0);
    }
}
