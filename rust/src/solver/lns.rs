//! Large-neighbourhood search polish (ruin-and-recreate).
//!
//! When the main DFS times out with a feasible-but-unproven incumbent,
//! LNS spends the remaining budget on randomised restarts: *ruin* a few
//! groups (un-fix their variables), freeze everything else to the
//! incumbent, and re-run an exact search on the small subproblem. Any
//! improvement replaces the incumbent. This mirrors CP-SAT's LNS workers
//! (scaled down) and is one of the ablation toggles.

use crate::telemetry::clock::Deadline;
use crate::util::rng::Rng;

use super::model::{Model, VarId};
use super::presolve::Structure;
use super::probe::Probe;
use super::search::{Searcher, SharedIncumbent, SolverConfig};
use super::solution::SearchStats;

/// Ruin-and-recreate loop. Returns the (possibly improved) incumbent.
/// In a portfolio race, `shared` propagates improvements to the other
/// racers and lets a cancellation end the polish early.
///
/// Forensics: LNS only engages on solves the DFS could *not* certify, so
/// its wall-clock-sliced rounds sit outside the profiler's cross-thread
/// identity claim. Move accounting (rounds, improvements, the gap
/// samples of improving rounds) is recorded under an `lns` context
/// frame; the sub-searchers themselves run with the probe off — their
/// slice boundaries are wall-clock-dependent, and attributing their
/// effort would leak that nondeterminism into the per-module table.
#[allow(clippy::too_many_arguments)]
pub fn lns_polish(
    model: &Model,
    structure: &Structure,
    obj: &[i64],
    mut best: Vec<bool>,
    mut best_val: i64,
    root_ub: i64,
    deadline: Deadline,
    config: &SolverConfig,
    shared: Option<&SharedIncumbent>,
    probe: &Probe,
    stats: &mut SearchStats,
) -> (Vec<bool>, i64) {
    let mut rng = Rng::new(config.seed);
    let ng = structure.groups.len();
    if ng == 0 {
        return (best, best_val);
    }
    let _lns_frame = probe.frame("lns");
    let off = Probe::off();
    // Neighbourhood size: a few groups; grows slowly when stuck.
    let mut ruin_size = 4.min(ng).max(1);

    while !deadline.expired() {
        if shared.is_some_and(|s| s.is_cancelled()) {
            break;
        }
        stats.lns_rounds += 1;

        // Pick the groups to ruin.
        let mut ruined = vec![false; ng];
        for _ in 0..ruin_size {
            ruined[rng.below(ng as u64) as usize] = true;
        }

        // Freeze everything outside the ruined groups to the incumbent.
        let mut fixes: Vec<(VarId, bool)> = Vec::new();
        for (gi, g) in structure.groups.iter().enumerate() {
            if ruined[gi] {
                continue;
            }
            for &v in &g.options {
                fixes.push((v, best[v.idx()]));
            }
        }

        // Exact search on the residual subproblem, small slice of time.
        let slice = Deadline::after(std::time::Duration::from_millis(50)).min(deadline);
        let sub_cfg = SolverConfig {
            use_lns: false,
            ..config.clone()
        };
        if let Some(mut s) = Searcher::new(model, structure, obj, slice, &sub_cfg, shared, &off) {
            if s.preassign(&fixes) {
                s.dfs(0, 0);
                s.drain_stats(stats);
                if let Some(vals) = s.best.take() {
                    if s.best_val > best_val {
                        best_val = s.best_val;
                        best = vals;
                        stats.lns_improvements += 1;
                        probe.attr("search", "improvements", 1);
                        // Gap sample indexed by LNS round, not wall clock.
                        probe.gap(stats.lns_rounds, best_val, root_ub);
                        ruin_size = 4.min(ng).max(1); // reset on success
                        continue;
                    }
                }
            }
        }
        // No improvement: widen the neighbourhood a little.
        ruin_size = (ruin_size + 1).min(ng.min(12));
    }
    probe.attr("search", "rounds", stats.lns_rounds);
    (best, best_val)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::model::LinearExpr;
    use crate::solver::presolve::detect_structure;
    use crate::solver::search::solve_max;
    use crate::solver::solution::SolveStatus;
    use std::time::Duration;

    /// LNS must never return something worse than the incumbent it got.
    #[test]
    fn never_degrades_incumbent() {
        let mut m = Model::new();
        let mut vars = Vec::new();
        let demands: Vec<i64> = (0..12).map(|i| 200 + (i * 53) % 300).collect();
        for _ in &demands {
            let xs = m.new_vars(3);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            vars.push(xs);
        }
        for node in 0..3 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&demands).map(|(xs, &d)| (xs[node], d))),
                900,
            );
        }
        let structure = detect_structure(&m);
        let mut obj = vec![0i64; m.num_vars()];
        for xs in &vars {
            for &v in xs {
                obj[v.idx()] = 1;
            }
        }
        // incumbent: nothing placed (feasible, value 0)
        let incumbent = vec![false; m.num_vars()];
        let mut stats = SearchStats::default();
        let probe = Probe::armed();
        let (vals, val) = lns_polish(
            &m,
            &structure,
            &obj,
            incumbent,
            0,
            demands.len() as i64,
            Deadline::after(Duration::from_millis(150)),
            &SolverConfig::default(),
            None,
            &probe,
            &mut stats,
        );
        assert!(val >= 0);
        assert!(m.feasible(&vals));
        assert!(stats.lns_rounds > 0);
        // with 150ms on a toy model, LNS should strictly improve over "place nothing"
        assert!(val > 0, "LNS failed to improve an empty incumbent");
        // Move accounting lands under the `lns` frame.
        let eff = probe.module_effort();
        let rounds: u64 = eff
            .iter()
            .filter(|(s, k, _)| s == "search" && *k == "rounds")
            .map(|&(_, _, n)| n)
            .sum();
        assert_eq!(rounds, stats.lns_rounds);
        assert!(probe.export_folded().contains("solve;lns;search;rounds"));
        let improvements: u64 = eff
            .iter()
            .filter(|(s, k, _)| s == "search" && *k == "improvements")
            .map(|&(_, _, n)| n)
            .sum();
        assert_eq!(improvements, stats.lns_improvements);
    }

    /// End-to-end: a model solved with a starving DFS deadline still comes
    /// back feasible thanks to the anytime behaviour + LNS.
    #[test]
    fn solve_with_lns_is_feasible() {
        let mut m = Model::new();
        let mut vars = Vec::new();
        let demands: Vec<i64> = (0..30).map(|i| 150 + (i * 91) % 500).collect();
        for _ in &demands {
            let xs = m.new_vars(6);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            vars.push(xs);
        }
        for node in 0..6 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&demands).map(|(xs, &d)| (xs[node], d))),
                1100,
            );
        }
        let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
        let sol = solve_max(
            &m,
            &obj,
            Deadline::after(Duration::from_millis(80)),
            &SolverConfig::default(),
        );
        assert!(matches!(sol.status, SolveStatus::Optimal | SolveStatus::Feasible));
        assert!(m.feasible(&sol.values));
    }
}
