//! From-scratch CP solver — the OR-Tools CP-SAT substitute.
//!
//! The paper's Algorithm 1 needs exactly this contract from its solver:
//!
//! * binary decision variables and **linear constraints** (`≤`, `≥`, `=`),
//! * `maximize(metric, timeout)` returning either a **proven OPTIMAL**
//!   solution or the best **FEASIBLE** incumbent found before the
//!   deadline (anytime behaviour),
//! * **solution hints** to warm-start from the current cluster
//!   assignment (CP-SAT's `AddHint`),
//! * model **re-solving** after appending constraints (CP-SAT has no
//!   push/pop; the paper re-solves after each place/move phase).
//!
//! The engine is a depth-first branch-and-bound specialised for (but not
//! limited to) assignment structure:
//!
//! * [`presolve`] detects *groups* — sets of variables under an
//!   at-most-one constraint (a pod's candidate nodes) — and branches on
//!   whole groups instead of single variables;
//! * [`propagate`] maintains bounds-consistency over all linear
//!   constraints with a trail for chronological backtracking;
//! * [`bound`] prunes with an admissible objective upper bound
//!   (fixed value + per-group open-option maxima);
//! * [`search`] runs the B&B with hint-first / best-fit value ordering,
//!   optional identical-node symmetry skipping, and adaptive deadline
//!   polling; its [`SharedIncumbent`] lets portfolio racers
//!   (`crate::portfolio`) share a global incumbent floor and cooperative
//!   cancellation without giving up determinism;
//! * [`lns`] optionally polishes a feasible incumbent with randomised
//!   ruin-and-recreate when time remains but optimality wasn't proven;
//! * [`probe`] optionally records solve forensics — per-constraint
//!   effort attribution and decision-indexed optimality-gap timelines —
//!   at zero overhead when off.
//!
//! All components are toggleable via [`SolverConfig`] — the ablation
//! bench (`benches/ablation.rs`) measures each one's contribution.

pub mod bound;
pub mod lns;
pub mod model;
pub mod presolve;
pub mod probe;
pub mod propagate;
pub mod search;
pub mod solution;

pub use model::{CmpOp, LinearExpr, Model, ResourceClass, VarId, UNTAGGED_PROVENANCE};
pub use probe::{GapSample, Probe, PROFILE_SCHEMA};
pub use search::{solve_max, solve_max_probed, solve_max_with, SharedIncumbent, SolverConfig};
pub use solution::{SearchStats, SolveStatus, Solution};
