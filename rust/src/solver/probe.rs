//! Solve forensics: a deterministic search profiler with per-constraint
//! attribution and optimality-gap timelines.
//!
//! A [`Probe`] is the forensic counterpart of
//! [`Telemetry`](crate::telemetry::Telemetry): an optional recorder
//! threaded through the solver core that is a zero-overhead no-op when
//! off ([`Probe::off`]) and, when armed, attributes search effort —
//! propagation work, conflicts, bound/floor prunes, symmetry skips — to
//! **constraint provenance** slugs
//! ([`Model::constraint_provenance`](super::model::Model)) so the
//! numbers map back to model semantics (capacity:cpu, anti-affinity,
//! lock, …), not row indices. It also records **optimality-gap
//! timelines** as `(decisions, incumbent, bound)` samples.
//!
//! # Determinism contract
//!
//! Everything a probe records is indexed by *decision count*, never wall
//! clock, and the portfolio arms it only on the canonical exact-search
//! lane (the legacy solve at one thread; the floor-detached whole-model
//! anchor otherwise). On solves the deadline does not truncate, the
//! profile is therefore **byte-identical across thread counts**, and
//! arming the probe never changes plans, objective vectors, or
//! certificates (pinned by `rust/tests/proptests.rs`). The profiler
//! lives in the detlint *core* zone on purpose: it must stay inside the
//! determinism boundary, and core code can never read a profile back
//! into decisions (the `telemetry-feedback` rule covers the read APIs).
//!
//! # Context frames
//!
//! Effort is recorded under a stack of context frames pushed by the
//! layers above (`t0.p1` per tier/phase from the optimiser, `exact` for
//! the canonical lane, `lns` inside the polish). The folded-stack export
//! renders one `frame;frame;slug;kind count` line per entry — directly
//! consumable by flamegraph.pl.

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::util::json::Json;

/// Schema identifier embedded in every profile JSON document.
pub const PROFILE_SCHEMA: &str = "kube-packd/profile/v1";

/// Root frame of every folded stack (so single-level records still form
/// a valid stack).
const ROOT_FRAME: &str = "solve";

/// One optimality-gap sample: the incumbent improved to `incumbent` at
/// `decisions` decisions, against admissible bound `bound`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GapSample {
    /// Context path at recording time (`;`-joined frames).
    pub context: String,
    pub decisions: u64,
    pub incumbent: i64,
    pub bound: i64,
}

#[derive(Debug, Default)]
struct Recorder {
    /// Current context-frame stack.
    stack: Vec<String>,
    /// (context path, provenance slug, effort kind) → count.
    effort: BTreeMap<(String, String, &'static str), u64>,
    gap: Vec<GapSample>,
}

impl Recorder {
    fn path(&self) -> String {
        if self.stack.is_empty() {
            ROOT_FRAME.to_string()
        } else {
            let mut p = ROOT_FRAME.to_string();
            for f in &self.stack {
                p.push(';');
                p.push_str(f);
            }
            p
        }
    }
}

/// The forensics handle. `Probe::off()` (the default) is a no-op shell —
/// every method early-returns without allocating.
#[derive(Debug, Default)]
pub struct Probe {
    inner: Option<RefCell<Recorder>>,
}

impl Probe {
    /// Disabled handle — all operations are no-ops.
    pub fn off() -> Probe {
        Probe { inner: None }
    }

    /// Enabled handle that records search forensics.
    pub fn armed() -> Probe {
        Probe {
            inner: Some(RefCell::new(Recorder::default())),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Push a context frame; the returned guard pops it on drop.
    pub fn frame(&self, label: &str) -> FrameGuard<'_> {
        if let Some(cell) = &self.inner {
            cell.borrow_mut().stack.push(label.to_string());
        }
        FrameGuard { probe: self }
    }

    /// Attribute `count` units of effort `kind` to provenance `slug`
    /// under the current context. Zero counts are dropped so profiles
    /// list only observed effort.
    pub fn attr(&self, slug: &str, kind: &'static str, count: u64) {
        if count == 0 {
            return;
        }
        if let Some(cell) = &self.inner {
            let mut r = cell.borrow_mut();
            let path = r.path();
            *r.effort.entry((path, slug.to_string(), kind)).or_insert(0) += count;
        }
    }

    /// Record an optimality-gap sample (decision-indexed, never wall
    /// clock — the determinism boundary).
    pub fn gap(&self, decisions: u64, incumbent: i64, bound: i64) {
        if let Some(cell) = &self.inner {
            let mut r = cell.borrow_mut();
            let context = r.path();
            r.gap.push(GapSample {
                context,
                decisions,
                incumbent,
                bound,
            });
        }
    }

    /// Spawn a handle for a portfolio race lane, inheriting the current
    /// context frames. Create on the owning thread before workers spawn;
    /// hand back via [`absorb`](Self::absorb) — exactly the
    /// `Telemetry::child` discipline.
    pub fn child(&self) -> Probe {
        match &self.inner {
            None => Probe::off(),
            Some(cell) => Probe {
                inner: Some(RefCell::new(Recorder {
                    stack: cell.borrow().stack.clone(),
                    effort: BTreeMap::new(),
                    gap: Vec::new(),
                })),
            },
        }
    }

    /// Merge a child handle's record into this one. Deterministic when
    /// callers absorb in a deterministic order; the race absorbs its one
    /// canonical lane after the thread scope ends.
    pub fn absorb(&self, child: Probe) {
        let cell = match &self.inner {
            Some(c) => c,
            None => return,
        };
        let ccell = match child.inner {
            Some(c) => c,
            None => return,
        };
        let c = ccell.into_inner();
        let mut r = cell.borrow_mut();
        for (key, n) in c.effort {
            *r.effort.entry(key).or_insert(0) += n;
        }
        r.gap.extend(c.gap);
    }

    /// Per-slug effort rollup, summed across contexts: sorted
    /// `(slug, kind, count)` triples. Read API — core code must not call
    /// this (detlint `telemetry-feedback`).
    pub fn module_effort(&self) -> Vec<(String, &'static str, u64)> {
        match &self.inner {
            None => Vec::new(),
            Some(cell) => rollup(&cell.borrow()),
        }
    }

    /// All recorded gap samples, in recording order. Read API — core
    /// code must not call this (detlint `telemetry-feedback`).
    pub fn gap_samples(&self) -> Vec<GapSample> {
        match &self.inner {
            None => Vec::new(),
            Some(cell) => cell.borrow().gap.clone(),
        }
    }

    /// flamegraph.pl-compatible folded stacks: one
    /// `frame;frame;slug;kind count` line per effort entry, sorted.
    /// Read API — core code must not call this (detlint
    /// `telemetry-feedback`).
    pub fn export_folded(&self) -> String {
        match &self.inner {
            None => String::new(),
            Some(cell) => render_folded(&cell.borrow()),
        }
    }

    /// The complete profile document (`kube-packd/profile/v1`): effort
    /// table, per-slug rollup, gap timeline, folded stacks. Read API —
    /// core code must not call this (detlint `telemetry-feedback`).
    pub fn export_profile_json(&self) -> String {
        match &self.inner {
            None => render_profile(&Recorder::default()),
            Some(cell) => render_profile(&cell.borrow()),
        }
    }
}

/// RAII context-frame guard from [`Probe::frame`].
pub struct FrameGuard<'a> {
    probe: &'a Probe,
}

impl Drop for FrameGuard<'_> {
    fn drop(&mut self) {
        if let Some(cell) = &self.probe.inner {
            cell.borrow_mut().stack.pop();
        }
    }
}

fn rollup(rec: &Recorder) -> Vec<(String, &'static str, u64)> {
    let mut sums: BTreeMap<(String, &'static str), u64> = BTreeMap::new();
    for ((_, slug, kind), &n) in &rec.effort {
        *sums.entry((slug.clone(), kind)).or_insert(0) += n;
    }
    sums.into_iter().map(|((s, k), n)| (s, k, n)).collect()
}

fn render_folded(rec: &Recorder) -> String {
    let mut out = String::new();
    for ((path, slug, kind), n) in &rec.effort {
        out.push_str(path);
        out.push(';');
        out.push_str(slug);
        out.push(';');
        out.push_str(kind);
        out.push(' ');
        out.push_str(&n.to_string());
        out.push('\n');
    }
    out
}

fn render_profile(rec: &Recorder) -> String {
    let mut doc = Json::obj();
    doc.set("schema", PROFILE_SCHEMA);

    let effort: Vec<Json> = rec
        .effort
        .iter()
        .map(|((path, slug, kind), &n)| {
            let mut e = Json::obj();
            e.set("context", path.as_str())
                .set("slug", slug.as_str())
                .set("kind", *kind)
                .set("count", n);
            e
        })
        .collect();
    doc.set("effort", Json::Arr(effort));

    let modules: Vec<Json> = rollup(rec)
        .into_iter()
        .map(|(slug, kind, n)| {
            let mut e = Json::obj();
            e.set("slug", slug).set("kind", kind).set("count", n);
            e
        })
        .collect();
    doc.set("modules", Json::Arr(modules));

    let gap: Vec<Json> = rec
        .gap
        .iter()
        .map(|s| {
            let mut e = Json::obj();
            e.set("context", s.context.as_str())
                .set("decisions", s.decisions)
                .set("incumbent", s.incumbent)
                .set("bound", s.bound);
            e
        })
        .collect();
    doc.set("gap", Json::Arr(gap));

    let folded: Vec<Json> = render_folded(rec)
        .lines()
        .map(Json::from)
        .collect();
    doc.set("folded", Json::Arr(folded));

    doc.to_string_pretty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn off_handle_is_inert() {
        let p = Probe::off();
        assert!(!p.enabled());
        {
            let _f = p.frame("t0.p1");
            p.attr("capacity:cpu", "propagations", 10);
            p.gap(1, 2, 3);
        }
        assert!(p.module_effort().is_empty());
        assert!(p.gap_samples().is_empty());
        assert_eq!(p.export_folded(), "");
        let doc = json::parse(&p.export_profile_json()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(PROFILE_SCHEMA));
        assert!(doc.get("effort").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn frames_nest_into_folded_paths() {
        let p = Probe::armed();
        {
            let _t = p.frame("t0.p1");
            let _e = p.frame("exact");
            p.attr("capacity:cpu", "propagations", 7);
            p.attr("capacity:cpu", "propagations", 3);
        }
        p.attr("search", "decisions", 5);
        let folded = p.export_folded();
        assert!(folded.contains("solve;t0.p1;exact;capacity:cpu;propagations 10"));
        assert!(folded.contains("solve;search;decisions 5"));
        // every folded line obeys the `stack;frames count` grammar
        for line in folded.lines() {
            let (stack, count) = line.rsplit_once(' ').unwrap();
            assert!(stack.split(';').count() >= 3, "{line}");
            count.parse::<u64>().unwrap();
        }
    }

    #[test]
    fn zero_counts_are_dropped() {
        let p = Probe::armed();
        p.attr("spread", "conflicts", 0);
        assert!(p.module_effort().is_empty());
    }

    #[test]
    fn child_inherits_frames_and_absorbs_in_order() {
        let p = Probe::armed();
        let _t = p.frame("t1.p2");
        let c = p.child();
        {
            let _e = c.frame("exact");
            c.attr("anti-affinity", "conflicts", 4);
            c.gap(12, 3, 5);
        }
        p.absorb(c);
        let folded = p.export_folded();
        assert!(folded.contains("solve;t1.p2;exact;anti-affinity;conflicts 4"));
        let gaps = p.gap_samples();
        assert_eq!(gaps.len(), 1);
        assert_eq!(gaps[0].context, "solve;t1.p2;exact");
        assert_eq!(gaps[0].decisions, 12);
    }

    #[test]
    fn rollup_sums_across_contexts() {
        let p = Probe::armed();
        {
            let _a = p.frame("t0.p1");
            p.attr("capacity:ram", "propagations", 6);
        }
        {
            let _b = p.frame("t1.p1");
            p.attr("capacity:ram", "propagations", 4);
        }
        assert_eq!(
            p.module_effort(),
            vec![("capacity:ram".to_string(), "propagations", 10)]
        );
    }

    #[test]
    fn profile_json_is_schema_stable_and_byte_stable() {
        let p = Probe::armed();
        {
            let _t = p.frame("t0.p1");
            p.attr("lock", "conflicts", 2);
            p.gap(9, 1, 3);
        }
        let a = p.export_profile_json();
        assert_eq!(a, p.export_profile_json());
        let doc = json::parse(&a).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(PROFILE_SCHEMA));
        let eff = doc.get("effort").unwrap().as_arr().unwrap();
        assert_eq!(eff.len(), 1);
        assert_eq!(eff[0].get("slug").unwrap().as_str(), Some("lock"));
        assert_eq!(eff[0].get("count").unwrap().as_i64(), Some(2));
        let gap = doc.get("gap").unwrap().as_arr().unwrap();
        assert_eq!(gap[0].get("decisions").unwrap().as_i64(), Some(9));
        assert_eq!(gap[0].get("incumbent").unwrap().as_i64(), Some(1));
        assert_eq!(gap[0].get("bound").unwrap().as_i64(), Some(3));
        let folded = doc.get("folded").unwrap().as_arr().unwrap();
        assert_eq!(
            folded[0].as_str(),
            Some("solve;t0.p1;lock;conflicts 2")
        );
    }
}
