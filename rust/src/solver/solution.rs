//! Solve results: status, assignment, and search statistics.

/// Mirrors CP-SAT's solve statuses (the subset Algorithm 1 branches on).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveStatus {
    /// Best possible objective, proven (search space exhausted or bound
    /// closed).
    Optimal,
    /// A feasible solution was found but optimality was not proven
    /// before the deadline.
    Feasible,
    /// Proven infeasible (no assignment satisfies the constraints).
    Infeasible,
    /// Deadline hit before any feasible assignment was found.
    Unknown,
}

impl SolveStatus {
    pub fn has_solution(self) -> bool {
        matches!(self, SolveStatus::Optimal | SolveStatus::Feasible)
    }
}

/// Search counters (exposed for perf work and the ablation bench).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SearchStats {
    pub decisions: u64,
    pub propagations: u64,
    pub conflicts: u64,
    pub bound_prunes: u64,
    pub symmetry_skips: u64,
    pub max_depth: u32,
    pub lns_rounds: u64,
    pub lns_improvements: u64,
    pub solve_time_s: f64,
}

/// Result of a `maximize` call.
#[derive(Clone, Debug)]
pub struct Solution {
    pub status: SolveStatus,
    /// Objective value of `values` (meaningful iff `status.has_solution()`).
    pub objective: i64,
    /// Complete variable assignment (empty iff no solution).
    pub values: Vec<bool>,
    pub stats: SearchStats,
}

impl Solution {
    pub fn infeasible(stats: SearchStats) -> Self {
        Solution {
            status: SolveStatus::Infeasible,
            objective: 0,
            values: Vec::new(),
            stats,
        }
    }

    pub fn unknown(stats: SearchStats) -> Self {
        Solution {
            status: SolveStatus::Unknown,
            objective: 0,
            values: Vec::new(),
            stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_has_solution() {
        assert!(SolveStatus::Optimal.has_solution());
        assert!(SolveStatus::Feasible.has_solution());
        assert!(!SolveStatus::Infeasible.has_solution());
        assert!(!SolveStatus::Unknown.has_solution());
    }
}
