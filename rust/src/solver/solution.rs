//! Solve results: status, assignment, and search statistics.

/// Mirrors CP-SAT's solve statuses (the subset Algorithm 1 branches on).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveStatus {
    /// Best possible objective, proven (search space exhausted or bound
    /// closed).
    Optimal,
    /// A feasible solution was found but optimality was not proven
    /// before the deadline.
    Feasible,
    /// Proven infeasible (no assignment satisfies the constraints).
    Infeasible,
    /// Deadline hit before any feasible assignment was found.
    Unknown,
}

impl SolveStatus {
    pub fn has_solution(self) -> bool {
        matches!(self, SolveStatus::Optimal | SolveStatus::Feasible)
    }

    /// Stable lower-case label for reports and JSON output.
    pub fn label(self) -> &'static str {
        match self {
            SolveStatus::Optimal => "optimal",
            SolveStatus::Feasible => "feasible",
            SolveStatus::Infeasible => "infeasible",
            SolveStatus::Unknown => "unknown",
        }
    }
}

/// Search counters (exposed for perf work and the ablation bench).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SearchStats {
    pub decisions: u64,
    pub propagations: u64,
    pub conflicts: u64,
    /// Subtrees cut by the admissible bound against the *local* incumbent.
    pub bound_prunes: u64,
    /// Subtrees cut specifically by the shared portfolio incumbent floor
    /// — work a sibling racer's published objective saved this search.
    /// Disjoint from `bound_prunes`: a node the local incumbent would
    /// also have cut counts there, not here.
    pub floor_prunes: u64,
    pub symmetry_skips: u64,
    pub max_depth: u32,
    pub lns_rounds: u64,
    pub lns_improvements: u64,
    pub solve_time_s: f64,
}

impl SearchStats {
    /// Accumulate another stats record into this one. Counters add;
    /// `max_depth` takes the maximum; `solve_time_s` adds (total solver
    /// time — for concurrent portfolio workers this is CPU-ish time, not
    /// wall-clock, and the portfolio layer overwrites it with the wall).
    pub fn merge(&mut self, other: &SearchStats) {
        self.decisions += other.decisions;
        self.propagations += other.propagations;
        self.conflicts += other.conflicts;
        self.bound_prunes += other.bound_prunes;
        self.floor_prunes += other.floor_prunes;
        self.symmetry_skips += other.symmetry_skips;
        self.max_depth = self.max_depth.max(other.max_depth);
        self.lns_rounds += other.lns_rounds;
        self.lns_improvements += other.lns_improvements;
        self.solve_time_s += other.solve_time_s;
    }

    /// Record every counter into a telemetry handle under `labels`
    /// (pre-rendered Prometheus label body, e.g. `strategy="default"`).
    /// All values here are deterministic outputs of a completed search,
    /// so the resulting counter dump is too.
    pub fn record(&self, tel: &crate::telemetry::Telemetry, labels: &str) {
        if !tel.enabled() {
            return;
        }
        tel.add("solver_decisions_total", labels, self.decisions);
        tel.add("solver_propagations_total", labels, self.propagations);
        tel.add("solver_conflicts_total", labels, self.conflicts);
        tel.add("solver_bound_prunes_total", labels, self.bound_prunes);
        tel.add("solver_floor_prunes_total", labels, self.floor_prunes);
        tel.add("solver_symmetry_skips_total", labels, self.symmetry_skips);
        tel.add("solver_lns_rounds_total", labels, self.lns_rounds);
        tel.add(
            "solver_lns_improvements_total",
            labels,
            self.lns_improvements,
        );
        tel.gauge_max("solver_max_depth", labels, self.max_depth as u64);
    }
}

/// Result of a `maximize` call.
#[derive(Clone, Debug)]
pub struct Solution {
    pub status: SolveStatus,
    /// Objective value of `values` (meaningful iff `status.has_solution()`).
    pub objective: i64,
    /// Admissible upper bound on the objective established by the solve:
    /// equal to `objective` when optimality was proven, otherwise the
    /// root relaxation bound. Together with `status` this is the
    /// per-solve *optimality certificate* — an anytime result is at most
    /// `bound - objective` away from optimal.
    pub bound: i64,
    /// Complete variable assignment (empty iff no solution).
    pub values: Vec<bool>,
    pub stats: SearchStats,
}

impl Solution {
    pub fn infeasible(stats: SearchStats) -> Self {
        Solution {
            status: SolveStatus::Infeasible,
            objective: 0,
            bound: 0,
            values: Vec::new(),
            stats,
        }
    }

    pub fn unknown(stats: SearchStats, bound: i64) -> Self {
        Solution {
            status: SolveStatus::Unknown,
            objective: 0,
            bound,
            values: Vec::new(),
            stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_has_solution() {
        assert!(SolveStatus::Optimal.has_solution());
        assert!(SolveStatus::Feasible.has_solution());
        assert!(!SolveStatus::Infeasible.has_solution());
        assert!(!SolveStatus::Unknown.has_solution());
    }

    #[test]
    fn status_labels_are_stable() {
        assert_eq!(SolveStatus::Optimal.label(), "optimal");
        assert_eq!(SolveStatus::Feasible.label(), "feasible");
        assert_eq!(SolveStatus::Infeasible.label(), "infeasible");
        assert_eq!(SolveStatus::Unknown.label(), "unknown");
    }

    #[test]
    fn stats_merge_adds_counters_and_maxes_depth() {
        let mut a = SearchStats {
            decisions: 3,
            max_depth: 2,
            solve_time_s: 0.5,
            ..Default::default()
        };
        let b = SearchStats {
            decisions: 4,
            max_depth: 7,
            solve_time_s: 0.25,
            lns_rounds: 2,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.decisions, 7);
        assert_eq!(a.max_depth, 7);
        assert_eq!(a.lns_rounds, 2);
        assert!((a.solve_time_s - 0.75).abs() < 1e-12);
    }

    #[test]
    fn helper_constructors_carry_bounds() {
        assert_eq!(Solution::infeasible(SearchStats::default()).bound, 0);
        assert_eq!(Solution::unknown(SearchStats::default(), 42).bound, 42);
    }
}
