//! The constraint model: binary variables, linear constraints, hints.

/// Dense variable index.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VarId(pub u32);

impl VarId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Comparison operator of a linear constraint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Le,
    Ge,
    Eq,
}

/// A linear expression `Σ coef·var` over binary variables.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinearExpr {
    pub terms: Vec<(VarId, i64)>,
}

impl LinearExpr {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, var: VarId, coef: i64) -> &mut Self {
        if coef != 0 {
            self.terms.push((var, coef));
        }
        self
    }

    pub fn of(terms: impl IntoIterator<Item = (VarId, i64)>) -> Self {
        let mut e = Self::new();
        for (v, c) in terms {
            e.add(v, c);
        }
        e
    }

    /// Merge duplicate variables (the propagator requires one term/var).
    pub fn normalized(mut self) -> Self {
        self.terms.sort_by_key(|(v, _)| *v);
        let mut out: Vec<(VarId, i64)> = Vec::with_capacity(self.terms.len());
        for (v, c) in self.terms {
            match out.last_mut() {
                Some((lv, lc)) if *lv == v => *lc += c,
                _ => out.push((v, c)),
            }
        }
        out.retain(|(_, c)| *c != 0);
        LinearExpr { terms: out }
    }

    /// Evaluate under a complete assignment.
    pub fn eval(&self, values: &[bool]) -> i64 {
        self.terms
            .iter()
            .map(|&(v, c)| if values[v.idx()] { c } else { 0 })
            .sum()
    }
}

/// `expr op rhs`.
#[derive(Clone, Debug, PartialEq)]
pub struct LinearConstraint {
    pub expr: LinearExpr,
    pub op: CmpOp,
    pub rhs: i64,
}

impl LinearConstraint {
    pub fn satisfied_by(&self, values: &[bool]) -> bool {
        let v = self.expr.eval(values);
        match self.op {
            CmpOp::Le => v <= self.rhs,
            CmpOp::Ge => v >= self.rhs,
            CmpOp::Eq => v == self.rhs,
        }
    }
}

/// Structure metadata for one *resource dimension*: the `≤`-constraint
/// indices that together cover it across all nodes, plus a human-readable
/// dimension name ("cpu", "ram", "gpu", …). The name is metadata only —
/// it surfaces in debug output and lets constraint modules declare
/// arbitrarily many named capacity dimensions — while the search engine
/// keys purely on the constraint indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResourceClass {
    pub name: String,
    pub cons: Vec<u32>,
}

/// Default provenance bucket for rows no builder tagged (hand-built
/// models, lock rows before tagging, …). The solve-forensics profiler
/// reports untagged effort here, never silently.
pub const UNTAGGED_PROVENANCE: &str = "search:other";

/// The model: a bag of variables, constraints, and optional hints.
/// Mirrors CP-SAT's `CpModel`: grow-only; re-solve after mutation.
#[derive(Clone, Debug, Default)]
pub struct Model {
    num_vars: u32,
    pub constraints: Vec<LinearConstraint>,
    /// Warm-start hint per variable (CP-SAT `AddHint`). Hinted values
    /// steer value ordering; they are never assumed valid.
    pub hints: Vec<Option<bool>>,
    /// Optional structure metadata: named groups of `≤`-constraint
    /// indices that partition one *resource dimension* (e.g. all nodes'
    /// CPU constraints). The search uses them for an aggregate fractional
    /// capacity bound — the counterpart of CP-SAT's knowledge that its
    /// knapsack constraints share items. Purely an optimisation: solvers
    /// ignore unknown classes, correctness never depends on them.
    pub resource_classes: Vec<ResourceClass>,
    /// Constraint provenance for solve forensics: one label id per
    /// constraint (possibly shorter than `constraints` — untagged tail
    /// rows report [`UNTAGGED_PROVENANCE`]). Id 0 is the untagged
    /// sentinel; id k ≥ 1 indexes `provenance_labels[k - 1]`. Metadata
    /// only: solvers never branch on it and the cache fingerprint
    /// ignores it.
    provenance: Vec<u16>,
    provenance_labels: Vec<String>,
}

impl Model {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn new_var(&mut self) -> VarId {
        let v = VarId(self.num_vars);
        self.num_vars += 1;
        self.hints.push(None);
        v
    }

    pub fn new_vars(&mut self, n: usize) -> Vec<VarId> {
        (0..n).map(|_| self.new_var()).collect()
    }

    pub fn num_vars(&self) -> usize {
        self.num_vars as usize
    }

    pub fn add_constraint(&mut self, expr: LinearExpr, op: CmpOp, rhs: i64) {
        self.constraints.push(LinearConstraint {
            expr: expr.normalized(),
            op,
            rhs,
        });
    }

    pub fn add_le(&mut self, expr: LinearExpr, rhs: i64) {
        self.add_constraint(expr, CmpOp::Le, rhs);
    }

    pub fn add_ge(&mut self, expr: LinearExpr, rhs: i64) {
        self.add_constraint(expr, CmpOp::Ge, rhs);
    }

    pub fn add_eq(&mut self, expr: LinearExpr, rhs: i64) {
        self.add_constraint(expr, CmpOp::Eq, rhs);
    }

    /// Declare that the given `≤` constraints together cover one
    /// (anonymous) resource dimension (see `resource_classes`).
    pub fn add_resource_class(&mut self, cons_indices: impl IntoIterator<Item = usize>) {
        self.add_named_resource_class("", cons_indices);
    }

    /// Declare a *named* resource dimension ("cpu", "gpu", …) covered by
    /// the given `≤` constraints.
    pub fn add_named_resource_class(
        &mut self,
        name: impl Into<String>,
        cons_indices: impl IntoIterator<Item = usize>,
    ) {
        self.resource_classes.push(ResourceClass {
            name: name.into(),
            cons: cons_indices.into_iter().map(|i| i as u32).collect(),
        });
    }

    /// Index the next constraint added will get.
    pub fn next_constraint_index(&self) -> usize {
        self.constraints.len()
    }

    /// Tag constraint `ci` with a provenance slug (solve forensics).
    /// Later tags overwrite earlier ones — the builder tags a module's
    /// whole emission range, then refines capacity rows per dimension.
    pub fn tag_constraint(&mut self, ci: usize, slug: &str) {
        if ci >= self.constraints.len() {
            return;
        }
        let id = match self.provenance_labels.iter().position(|l| l == slug) {
            Some(i) => (i + 1) as u16,
            None => {
                self.provenance_labels.push(slug.to_string());
                self.provenance_labels.len() as u16
            }
        };
        if self.provenance.len() <= ci {
            self.provenance.resize(ci + 1, 0);
        }
        self.provenance[ci] = id;
    }

    /// Tag every constraint from index `from` (inclusive) to the current
    /// end with a provenance slug — the builder brackets each module's
    /// `emit` with `next_constraint_index` / `tag_constraints`.
    pub fn tag_constraints(&mut self, from: usize, slug: &str) {
        for ci in from..self.constraints.len() {
            self.tag_constraint(ci, slug);
        }
    }

    /// Provenance slug of constraint `ci` ([`UNTAGGED_PROVENANCE`] when
    /// never tagged).
    pub fn constraint_provenance(&self, ci: usize) -> &str {
        match self.provenance.get(ci) {
            Some(&id) if id > 0 => &self.provenance_labels[(id - 1) as usize],
            _ => UNTAGGED_PROVENANCE,
        }
    }

    /// Set a warm-start hint for one variable.
    pub fn hint(&mut self, var: VarId, value: bool) {
        self.hints[var.idx()] = Some(value);
    }

    /// Clear all hints (before installing a fresh assignment).
    pub fn clear_hints(&mut self) {
        for h in &mut self.hints {
            *h = None;
        }
    }

    /// Check a complete assignment against every constraint.
    pub fn feasible(&self, values: &[bool]) -> bool {
        assert_eq!(values.len(), self.num_vars());
        self.constraints.iter().all(|c| c.satisfied_by(values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expr_normalization_merges_terms() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        let e = LinearExpr::of([(a, 1), (b, 2), (a, 3), (b, -2)]).normalized();
        assert_eq!(e.terms, vec![(a, 4)]);
    }

    #[test]
    fn eval_and_satisfaction() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        m.add_le(LinearExpr::of([(a, 2), (b, 3)]), 4);
        assert!(m.feasible(&[true, false]));
        assert!(!m.feasible(&[true, true]));
        m.add_ge(LinearExpr::of([(a, 1)]), 1);
        assert!(m.feasible(&[true, false]));
        assert!(!m.feasible(&[false, false]));
        m.add_eq(LinearExpr::of([(b, 1)]), 0);
        assert!(m.feasible(&[true, false]));
        assert!(!m.feasible(&[true, true]));
    }

    #[test]
    fn provenance_tags_round_trip_and_default() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        m.add_le(LinearExpr::of([(a, 1)]), 1);
        assert_eq!(m.constraint_provenance(0), UNTAGGED_PROVENANCE);
        let from = m.next_constraint_index();
        m.add_le(LinearExpr::of([(b, 1)]), 1);
        m.add_le(LinearExpr::of([(a, 1), (b, 1)]), 1);
        m.tag_constraints(from, "capacity");
        m.tag_constraint(2, "anti-affinity");
        assert_eq!(m.constraint_provenance(0), UNTAGGED_PROVENANCE);
        assert_eq!(m.constraint_provenance(1), "capacity");
        assert_eq!(m.constraint_provenance(2), "anti-affinity");
        // out of range: default, no panic
        assert_eq!(m.constraint_provenance(99), UNTAGGED_PROVENANCE);
        // tags survive Clone
        let c = m.clone();
        assert_eq!(c.constraint_provenance(1), "capacity");
    }

    #[test]
    fn hints_tracked_per_var() {
        let mut m = Model::new();
        let a = m.new_var();
        let _b = m.new_var();
        m.hint(a, true);
        assert_eq!(m.hints, vec![Some(true), None]);
        m.clear_hints();
        assert_eq!(m.hints, vec![None, None]);
    }
}
