//! The constraint model: binary variables, linear constraints, hints.

/// Dense variable index.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VarId(pub u32);

impl VarId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Comparison operator of a linear constraint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Le,
    Ge,
    Eq,
}

/// A linear expression `Σ coef·var` over binary variables.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinearExpr {
    pub terms: Vec<(VarId, i64)>,
}

impl LinearExpr {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, var: VarId, coef: i64) -> &mut Self {
        if coef != 0 {
            self.terms.push((var, coef));
        }
        self
    }

    pub fn of(terms: impl IntoIterator<Item = (VarId, i64)>) -> Self {
        let mut e = Self::new();
        for (v, c) in terms {
            e.add(v, c);
        }
        e
    }

    /// Merge duplicate variables (the propagator requires one term/var).
    pub fn normalized(mut self) -> Self {
        self.terms.sort_by_key(|(v, _)| *v);
        let mut out: Vec<(VarId, i64)> = Vec::with_capacity(self.terms.len());
        for (v, c) in self.terms {
            match out.last_mut() {
                Some((lv, lc)) if *lv == v => *lc += c,
                _ => out.push((v, c)),
            }
        }
        out.retain(|(_, c)| *c != 0);
        LinearExpr { terms: out }
    }

    /// Evaluate under a complete assignment.
    pub fn eval(&self, values: &[bool]) -> i64 {
        self.terms
            .iter()
            .map(|&(v, c)| if values[v.idx()] { c } else { 0 })
            .sum()
    }
}

/// `expr op rhs`.
#[derive(Clone, Debug, PartialEq)]
pub struct LinearConstraint {
    pub expr: LinearExpr,
    pub op: CmpOp,
    pub rhs: i64,
}

impl LinearConstraint {
    pub fn satisfied_by(&self, values: &[bool]) -> bool {
        let v = self.expr.eval(values);
        match self.op {
            CmpOp::Le => v <= self.rhs,
            CmpOp::Ge => v >= self.rhs,
            CmpOp::Eq => v == self.rhs,
        }
    }
}

/// Structure metadata for one *resource dimension*: the `≤`-constraint
/// indices that together cover it across all nodes, plus a human-readable
/// dimension name ("cpu", "ram", "gpu", …). The name is metadata only —
/// it surfaces in debug output and lets constraint modules declare
/// arbitrarily many named capacity dimensions — while the search engine
/// keys purely on the constraint indices.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResourceClass {
    pub name: String,
    pub cons: Vec<u32>,
}

/// The model: a bag of variables, constraints, and optional hints.
/// Mirrors CP-SAT's `CpModel`: grow-only; re-solve after mutation.
#[derive(Clone, Debug, Default)]
pub struct Model {
    num_vars: u32,
    pub constraints: Vec<LinearConstraint>,
    /// Warm-start hint per variable (CP-SAT `AddHint`). Hinted values
    /// steer value ordering; they are never assumed valid.
    pub hints: Vec<Option<bool>>,
    /// Optional structure metadata: named groups of `≤`-constraint
    /// indices that partition one *resource dimension* (e.g. all nodes'
    /// CPU constraints). The search uses them for an aggregate fractional
    /// capacity bound — the counterpart of CP-SAT's knowledge that its
    /// knapsack constraints share items. Purely an optimisation: solvers
    /// ignore unknown classes, correctness never depends on them.
    pub resource_classes: Vec<ResourceClass>,
}

impl Model {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn new_var(&mut self) -> VarId {
        let v = VarId(self.num_vars);
        self.num_vars += 1;
        self.hints.push(None);
        v
    }

    pub fn new_vars(&mut self, n: usize) -> Vec<VarId> {
        (0..n).map(|_| self.new_var()).collect()
    }

    pub fn num_vars(&self) -> usize {
        self.num_vars as usize
    }

    pub fn add_constraint(&mut self, expr: LinearExpr, op: CmpOp, rhs: i64) {
        self.constraints.push(LinearConstraint {
            expr: expr.normalized(),
            op,
            rhs,
        });
    }

    pub fn add_le(&mut self, expr: LinearExpr, rhs: i64) {
        self.add_constraint(expr, CmpOp::Le, rhs);
    }

    pub fn add_ge(&mut self, expr: LinearExpr, rhs: i64) {
        self.add_constraint(expr, CmpOp::Ge, rhs);
    }

    pub fn add_eq(&mut self, expr: LinearExpr, rhs: i64) {
        self.add_constraint(expr, CmpOp::Eq, rhs);
    }

    /// Declare that the given `≤` constraints together cover one
    /// (anonymous) resource dimension (see `resource_classes`).
    pub fn add_resource_class(&mut self, cons_indices: impl IntoIterator<Item = usize>) {
        self.add_named_resource_class("", cons_indices);
    }

    /// Declare a *named* resource dimension ("cpu", "gpu", …) covered by
    /// the given `≤` constraints.
    pub fn add_named_resource_class(
        &mut self,
        name: impl Into<String>,
        cons_indices: impl IntoIterator<Item = usize>,
    ) {
        self.resource_classes.push(ResourceClass {
            name: name.into(),
            cons: cons_indices.into_iter().map(|i| i as u32).collect(),
        });
    }

    /// Index the next constraint added will get.
    pub fn next_constraint_index(&self) -> usize {
        self.constraints.len()
    }

    /// Set a warm-start hint for one variable.
    pub fn hint(&mut self, var: VarId, value: bool) {
        self.hints[var.idx()] = Some(value);
    }

    /// Clear all hints (before installing a fresh assignment).
    pub fn clear_hints(&mut self) {
        for h in &mut self.hints {
            *h = None;
        }
    }

    /// Check a complete assignment against every constraint.
    pub fn feasible(&self, values: &[bool]) -> bool {
        assert_eq!(values.len(), self.num_vars());
        self.constraints.iter().all(|c| c.satisfied_by(values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expr_normalization_merges_terms() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        let e = LinearExpr::of([(a, 1), (b, 2), (a, 3), (b, -2)]).normalized();
        assert_eq!(e.terms, vec![(a, 4)]);
    }

    #[test]
    fn eval_and_satisfaction() {
        let mut m = Model::new();
        let a = m.new_var();
        let b = m.new_var();
        m.add_le(LinearExpr::of([(a, 2), (b, 3)]), 4);
        assert!(m.feasible(&[true, false]));
        assert!(!m.feasible(&[true, true]));
        m.add_ge(LinearExpr::of([(a, 1)]), 1);
        assert!(m.feasible(&[true, false]));
        assert!(!m.feasible(&[false, false]));
        m.add_eq(LinearExpr::of([(b, 1)]), 0);
        assert!(m.feasible(&[true, false]));
        assert!(!m.feasible(&[true, true]));
    }

    #[test]
    fn hints_tracked_per_var() {
        let mut m = Model::new();
        let a = m.new_var();
        let _b = m.new_var();
        m.hint(a, true);
        assert_eq!(m.hints, vec![Some(true), None]);
        m.clear_hints();
        assert_eq!(m.hints, vec![None, None]);
    }
}
