//! Admissible objective upper bounds for the branch-and-bound.
//!
//! The search maintains the bound *incrementally* (see
//! [`super::search`]); this module holds the from-scratch computation
//! used to (a) seed the root bound, (b) cross-check the incremental
//! value in debug builds, and (c) provide the bound for tests.
//!
//! For a maximisation over groups (at most one option true per group):
//!
//! ```text
//! UB = Σ_{v fixed true} obj[v]
//!    + Σ_{g undecided} max(0, max_{v ∈ g, v unknown} obj[v])
//! ```
//!
//! This is admissible: any completion picks ≤ 1 open option per
//! undecided group (contributing at most the group max, or 0 for none)
//! and cannot un-fix fixed variables.

use super::model::VarId;
use super::presolve::Structure;
use super::propagate::Propagator;

/// Full recomputation of the upper bound.
pub fn upper_bound(prop: &Propagator, structure: &Structure, obj: &[i64]) -> i64 {
    let mut ub = 0i64;
    for g in &structure.groups {
        let mut chosen = 0i64;
        let mut decided = false;
        let mut best_open = 0i64;
        for &v in &g.options {
            match prop.value(v) {
                Some(true) => {
                    chosen += obj[v.idx()];
                    decided = true;
                }
                Some(false) => {}
                None => best_open = best_open.max(obj[v.idx()]),
            }
        }
        ub += if decided { chosen } else { best_open.max(0) };
    }
    ub
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::model::{LinearExpr, Model};
    use crate::solver::presolve::detect_structure;

    fn obj_vec(n: usize, pairs: &[(VarId, i64)]) -> Vec<i64> {
        let mut o = vec![0i64; n];
        for &(v, c) in pairs {
            o[v.idx()] = c;
        }
        o
    }

    #[test]
    fn root_bound_sums_group_maxima() {
        let mut m = Model::new();
        let xs = m.new_vars(3);
        let ys = m.new_vars(3);
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        m.add_le(LinearExpr::of(ys.iter().map(|&v| (v, 1))), 1);
        let s = detect_structure(&m);
        let obj = obj_vec(
            6,
            &[(xs[0], 1), (xs[1], 3), (xs[2], 2), (ys[0], 5), (ys[1], 1), (ys[2], 1)],
        );
        let p = Propagator::new(&m).unwrap();
        assert_eq!(upper_bound(&p, &s, &obj), 3 + 5);
    }

    #[test]
    fn bound_tightens_as_vars_fix() {
        let mut m = Model::new();
        let xs = m.new_vars(2);
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        let s = detect_structure(&m);
        let obj = obj_vec(2, &[(xs[0], 10), (xs[1], 4)]);
        let mut p = Propagator::new(&m).unwrap();
        assert_eq!(upper_bound(&p, &s, &obj), 10);
        p.push_level();
        p.decide(xs[0], false);
        assert_eq!(upper_bound(&p, &s, &obj), 4);
        p.push_level();
        p.decide(xs[1], true);
        assert_eq!(upper_bound(&p, &s, &obj), 4);
    }

    #[test]
    fn negative_objective_options_floor_at_zero() {
        let mut m = Model::new();
        let xs = m.new_vars(2);
        m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
        let s = detect_structure(&m);
        let obj = obj_vec(2, &[(xs[0], -5), (xs[1], -2)]);
        let p = Propagator::new(&m).unwrap();
        // choosing none (0) dominates any negative option
        assert_eq!(upper_bound(&p, &s, &obj), 0);
    }
}
