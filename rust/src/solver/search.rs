//! Depth-first branch-and-bound over variable groups.
//!
//! Branching unit: a *group* (one pod's candidate nodes — see
//! [`super::presolve`]). At each node the search picks the hardest
//! undecided group (static difficulty order) and branches over its open
//! options (hint-first, then best-fit) plus the "place nowhere" branch.
//! Propagation ([`super::propagate`]) closes each decision; the
//! incremental objective bound (cross-checked against
//! [`super::bound::upper_bound`] in debug builds) prunes dominated
//! subtrees; the anytime incumbent is returned on deadline expiry.
//!
//! Symmetry skipping: two open options of one group whose *signature* —
//! objective coefficient plus (coef, residual, op, rhs) over every
//! constraint they appear in — is identical are exchangeable in the
//! models this project generates (identical-capacity nodes make node
//! columns isomorphic: every tier variable appears in every node's
//! CPU/RAM constraint with the same demand coefficient). Only the first
//! of an equivalence class is branched on; `rust/tests/proptests.rs`
//! cross-validates optima with the feature on and off.

use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::time::Instant;

use crate::telemetry::clock::Deadline;

use super::bound::upper_bound;
use super::lns::lns_polish;
use super::model::{CmpOp, LinearExpr, Model, VarId, UNTAGGED_PROVENANCE};
use super::presolve::{detect_structure_probed, Structure};
use super::probe::Probe;
use super::propagate::Propagator;
use super::solution::{SearchStats, SolveStatus, Solution};

/// Feature toggles (every one is exercised by `benches/ablation.rs`).
#[derive(Clone, Debug)]
pub struct SolverConfig {
    /// Prune with the admissible objective upper bound.
    pub use_bound: bool,
    /// Tighten the bound with the aggregate fractional-capacity count
    /// over declared resource classes (uniform objectives only). This is
    /// what lets the solver *prove* optimality on ≈100%-usage instances
    /// instead of enumerating the whole assignment space.
    pub use_capacity_bound: bool,
    /// Use model hints for value ordering (warm start).
    pub use_hints: bool,
    /// Best-fit value ordering (tightest residual first) after hints.
    pub use_best_fit: bool,
    /// Skip exchangeable options (identical-node symmetry).
    pub use_symmetry: bool,
    /// Polish timed-out incumbents with LNS (ruin-and-recreate).
    pub use_lns: bool,
    /// Fraction of the deadline reserved for LNS when enabled.
    pub lns_fraction: f64,
    /// Branch easiest group first instead of the classic hardest-first
    /// bin-packing order. A portfolio diversification knob: the reversed
    /// order explores a complementary part of the tree, so a racer with
    /// it on finds different early incumbents than the default order.
    pub branch_easiest_first: bool,
    /// *Initial* deadline-poll interval, in decisions, capped at the
    /// adaptive minimum (4) so the very first wall-clock check happens
    /// before a tiny window can be overshot on an expensive instance.
    /// After that first check the interval adapts to the measured
    /// decision rate — backing off while decisions are cheap, tightening
    /// as the deadline nears (see `Searcher::poll_deadline`).
    pub check_interval: u64,
    /// Seed for LNS randomisation.
    pub seed: u64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            use_bound: true,
            use_capacity_bound: true,
            use_hints: true,
            use_best_fit: true,
            use_symmetry: true,
            use_lns: true,
            lns_fraction: 0.25,
            branch_easiest_first: false,
            check_interval: 64,
            seed: 0x5EED,
        }
    }
}

/// Cross-worker coordination for a portfolio race over one model: a
/// monotone global *floor* (best objective any racer has published,
/// shared between [`SharedIncumbent::sibling`] handles) and a
/// **per-handle** cooperative cancellation flag.
///
/// Determinism: racers prune only subtrees whose bound is **strictly**
/// below the floor. The floor never exceeds the model's true optimum
/// (it is always some racer's feasible objective), so a racer that runs
/// to completion still reaches the same first-in-DFS-order optimal leaf
/// it would have found alone — sharing accelerates losers, it never
/// changes a completing winner's answer. Cancellation is per handle so
/// the portfolio can stop exactly the racers whose results are provably
/// irrelevant (higher ranks after a proof) and no one else.
#[derive(Debug)]
pub struct SharedIncumbent {
    /// Best objective published by any sibling (`i64::MIN` = none yet).
    floor: std::sync::Arc<AtomicI64>,
    cancel: AtomicBool,
}

impl Default for SharedIncumbent {
    fn default() -> Self {
        Self::new()
    }
}

impl SharedIncumbent {
    pub fn new() -> Self {
        SharedIncumbent {
            floor: std::sync::Arc::new(AtomicI64::new(i64::MIN)),
            cancel: AtomicBool::new(false),
        }
    }

    /// A handle pre-seeded with a known feasible objective — an
    /// incremental session's previous incumbent projected onto the
    /// current model. Racers prune strictly below it from their very
    /// first decision; because the seed is some feasible assignment's
    /// objective (never above the true optimum), a completing search
    /// still returns the same first-in-DFS-order answer it finds alone.
    pub fn seeded(floor: i64) -> SharedIncumbent {
        let s = SharedIncumbent::new();
        s.publish(floor);
        s
    }

    /// A handle sharing this one's floor but carrying its own
    /// cancellation flag (shared incumbent, per-racer cancel).
    pub fn sibling(&self) -> SharedIncumbent {
        SharedIncumbent {
            floor: std::sync::Arc::clone(&self.floor),
            cancel: AtomicBool::new(false),
        }
    }

    /// Raise the floor to `objective` (monotone; racers call this on
    /// every incumbent improvement).
    pub fn publish(&self, objective: i64) {
        self.floor.fetch_max(objective, Ordering::Relaxed);
    }

    pub fn floor(&self) -> i64 {
        self.floor.load(Ordering::Relaxed)
    }

    /// Ask the racer holding *this* handle to stop at its next poll.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// Adaptive deadline-poll interval clamp, in decisions. The lower bound
/// keeps even propagation-heavy instances (milliseconds per decision)
/// from overshooting tiny windows by more than a few decisions; the
/// upper bound keeps cancellation latency bounded on cheap instances.
const MIN_POLL_INTERVAL: u64 = 4;
const MAX_POLL_INTERVAL: u64 = 8192;

/// Maximise `objective` over `model` within `deadline`.
pub fn solve_max(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    config: &SolverConfig,
) -> Solution {
    solve_max_with(model, objective, deadline, config, None)
}

/// [`solve_max`] with an optional [`SharedIncumbent`] for portfolio
/// racing: incumbent improvements are published to the handle, its floor
/// prunes strictly-dominated subtrees, and its cancellation flag stops
/// the search (reported like a timeout, but without an LNS polish —
/// a cancelled racer's window belongs to whoever proved optimality).
pub fn solve_max_with(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    config: &SolverConfig,
    shared: Option<&SharedIncumbent>,
) -> Solution {
    solve_max_probed(model, objective, deadline, config, shared, &Probe::off())
}

/// [`solve_max_with`] plus solve forensics: when `probe` is armed,
/// propagation work and conflicts are attributed to constraint
/// provenance ([`Model::constraint_provenance`]), search-level effort
/// (decisions, bound/floor prunes, symmetry skips) lands in the
/// `search:*` buckets, and every incumbent improvement appends a
/// decision-indexed optimality-gap sample. Arming the probe never
/// changes the search: value ordering, pruning, and the returned
/// solution are bit-for-bit those of the unprobed solve
/// (`rust/tests/proptests.rs` pins this).
pub fn solve_max_probed(
    model: &Model,
    objective: &LinearExpr,
    deadline: Deadline,
    config: &SolverConfig,
    shared: Option<&SharedIncumbent>,
    probe: &Probe,
) -> Solution {
    // detlint: allow(wall-clock) — the solve stopwatch and deadline anchor:
    // the one sanctioned time source for anytime termination.
    let started = Instant::now();
    let mut stats = SearchStats::default();

    let structure = detect_structure_probed(model, probe);
    let mut obj = vec![0i64; model.num_vars()];
    for &(v, c) in &objective.clone().normalized().terms {
        obj[v.idx()] = c;
    }

    let dfs_deadline = if config.use_lns {
        Deadline::after(deadline.remaining().mul_f64(1.0 - config.lns_fraction)).min(deadline)
    } else {
        deadline
    };

    let mut searcher =
        match Searcher::new(model, &structure, &obj, dfs_deadline, config, shared, probe) {
            Some(s) => s,
            None => {
                stats.solve_time_s = started.elapsed().as_secs_f64();
                return Solution::infeasible(stats);
            }
        };
    searcher.dfs(0, 0);
    searcher.drain_stats(&mut stats);
    searcher.flush_probe();

    let complete = !searcher.timed_out;
    let root_ub = searcher.root_ub;
    let cancelled = searcher.cancelled;
    let mut proven_optimal =
        complete || searcher.best.as_ref().map(|_| searcher.best_val >= root_ub).unwrap_or(false);
    let mut best = searcher.best.take();
    let mut best_val = searcher.best_val;

    // LNS polish: only useful when we have a feasible-but-unproven incumbent.
    if config.use_lns && !proven_optimal && !cancelled && best.is_some() && !deadline.expired() {
        let (nb, nv) = lns_polish(
            model,
            &structure,
            &obj,
            best.clone().unwrap(),
            best_val,
            root_ub,
            deadline,
            config,
            shared,
            probe,
            &mut stats,
        );
        best = Some(nb);
        best_val = nv;
        // LNS can close the root gap; credit the proof when it does.
        proven_optimal = proven_optimal || best_val >= root_ub;
    }

    stats.solve_time_s = started.elapsed().as_secs_f64();
    match best {
        Some(values) => Solution {
            status: if proven_optimal {
                SolveStatus::Optimal
            } else {
                SolveStatus::Feasible
            },
            objective: best_val,
            bound: if proven_optimal { best_val } else { root_ub },
            values,
            stats,
        },
        None if complete => Solution::infeasible(stats),
        None => Solution::unknown(stats, root_ub),
    }
}

/// One resource class prepared for the aggregate capacity bound.
struct CapClass {
    /// Constraint indices of this class (e.g. every node's CPU row).
    cons: Vec<u32>,
    /// `(demand, group)` ascending by demand; demand = the group's
    /// coefficient in this class (0 if it does not consume it).
    demands: Vec<(i64, u32)>,
}

/// One DFS run. Also reused by LNS with pre-fixed variables.
pub(super) struct Searcher<'a> {
    model: &'a Model,
    structure: &'a Structure,
    obj: &'a [i64],
    config: &'a SolverConfig,
    prop: Propagator,
    /// Static branching order: group indices, hardest first.
    order: Vec<u32>,
    /// Per-group: number of options fixed true / still unknown.
    group_true: Vec<u32>,
    group_open: Vec<u32>,
    /// Per-group current potential contribution to the bound.
    group_contrib: Vec<i64>,
    /// Σ group_contrib over undecided groups.
    potential: i64,
    /// Σ obj[v] over fixed-true vars.
    fixed_obj: i64,
    /// Per-var knapsack participation for best-fit keys: (cons, coef).
    knap: Vec<Vec<(u32, i64)>>,
    knap_rhs: Vec<i64>,
    /// Capacity-bound support: per resource class, its constraints and
    /// the per-group demands sorted ascending. Empty when disabled or
    /// the objective is not uniform.
    cap_classes: Vec<CapClass>,
    /// The uniform per-placement objective weight (capacity bound scale).
    cap_weight: i64,
    /// Per-var full participation for symmetry signatures.
    all_occ: Vec<Vec<(u32, i64)>>,
    cons_rhs: Vec<i64>,
    cons_op: Vec<CmpOp>,
    pub best: Option<Vec<bool>>,
    pub best_val: i64,
    pub root_ub: i64,
    deadline: Deadline,
    pub timed_out: bool,
    /// Stopped by a [`SharedIncumbent`] cancellation (subset of
    /// `timed_out`; tells the caller to skip the LNS polish).
    pub cancelled: bool,
    /// Portfolio-race handle: publish incumbents, read the floor, honour
    /// cancellation. `None` outside a race.
    shared: Option<&'a SharedIncumbent>,
    /// Cached copy of the shared floor (refreshed at poll points).
    floor: i64,
    decisions: u64,
    /// Decision count at which the deadline is next polled.
    next_poll: u64,
    last_poll: Instant,
    last_poll_decisions: u64,
    conflicts: u64,
    bound_prunes: u64,
    /// Prunes where the shared race floor alone cut the subtree (the
    /// local incumbent would not have) — sibling-racer savings.
    floor_prunes: u64,
    symmetry_skips: u64,
    max_depth: u32,
    /// Solve-forensics handle ([`Probe::off`] outside profiled solves).
    probe: &'a Probe,
    /// Per-constraint conflict counts (probe armed only).
    conflict_attr: Option<Vec<u64>>,
    /// Conflicts the propagator could not pin to a constraint (e.g. an
    /// assignment contradicting the trail directly).
    unattributed_conflicts: u64,
}

impl<'a> Searcher<'a> {
    /// Build and root-propagate; `None` = infeasible at the root.
    pub(super) fn new(
        model: &'a Model,
        structure: &'a Structure,
        obj: &'a [i64],
        deadline: Deadline,
        config: &'a SolverConfig,
        shared: Option<&'a SharedIncumbent>,
        probe: &'a Probe,
    ) -> Option<Self> {
        let prop = Propagator::new_probed(model, probe.enabled())?;
        let nv = model.num_vars();
        let ng = structure.groups.len();

        // Best-fit knapsack lists: Le constraints that are not at-most-one.
        let mut knap: Vec<Vec<(u32, i64)>> = vec![Vec::new(); nv];
        let mut all_occ: Vec<Vec<(u32, i64)>> = vec![Vec::new(); nv];
        let mut knap_rhs = vec![0i64; model.constraints.len()];
        for (ci, c) in model.constraints.iter().enumerate() {
            let is_amo =
                c.op == CmpOp::Le && c.rhs == 1 && c.expr.terms.iter().all(|&(_, k)| k == 1);
            knap_rhs[ci] = c.rhs;
            for &(v, coef) in &c.expr.terms {
                if !is_amo {
                    all_occ[v.idx()].push((ci as u32, coef));
                    if c.op == CmpOp::Le {
                        knap[v.idx()].push((ci as u32, coef));
                    }
                }
            }
        }

        // Static branching order. Two segments:
        //   1. *hinted* groups (one option hinted true) — deciding them
        //      first makes the first DFS descent reproduce the warm-start
        //      solution, which satisfies all accumulated phase locks; a
        //      feasible incumbent then exists within |groups| decisions.
        //      Without this, equality locks from earlier tiers conflict
        //      deep in the tree and chronological backtracking thrashes.
        //   2. unhinted groups.
        // Within each segment: decreasing max knapsack share (hardest
        // first), the classic bin-packing order.
        let difficulty = |g: &super::presolve::Group| -> f64 {
            g.options
                .iter()
                .flat_map(|v| knap[v.idx()].iter())
                .map(|&(ci, coef)| coef as f64 / (knap_rhs[ci as usize].max(1)) as f64)
                .fold(0.0f64, f64::max)
        };
        let hinted_group = |g: &super::presolve::Group| -> bool {
            config.use_hints && g.options.iter().any(|v| model.hints[v.idx()] == Some(true))
        };
        let mut order: Vec<u32> = (0..ng as u32).collect();
        let keys: Vec<(bool, f64)> = structure
            .groups
            .iter()
            .map(|g| (!hinted_group(g), difficulty(g)))
            .collect();
        // Hinted first, then difficulty desc (or asc under the
        // portfolio's `branch_easiest_first` diversification variant).
        order.sort_by(|&a, &b| {
            let (ha, da) = keys[a as usize];
            let (hb, db) = keys[b as usize];
            let by_difficulty = key_order(da, db, config.branch_easiest_first);
            ha.cmp(&hb).then(by_difficulty).then(a.cmp(&b))
        });
        drop(keys);

        // Aggregate capacity bound preparation: only when classes are
        // declared and the objective is uniform (every non-zero objective
        // coefficient equals one weight w) — the phase-1 "count placed
        // pods" shape. Phase-2 objectives (3/1 weights) fall back to the
        // group-potential bound alone.
        let mut cap_classes: Vec<CapClass> = Vec::new();
        let mut cap_weight = 0i64;
        if config.use_capacity_bound && !model.resource_classes.is_empty() {
            let mut weights: Vec<i64> = obj.iter().copied().filter(|&c| c != 0).collect();
            weights.sort_unstable();
            weights.dedup();
            if weights.len() == 1 && weights[0] > 0 {
                cap_weight = weights[0];
                let nc = model.constraints.len();
                let mut class_of = vec![u32::MAX; nc];
                for (k, class) in model.resource_classes.iter().enumerate() {
                    for &ci in &class.cons {
                        class_of[ci as usize] = k as u32;
                    }
                }
                let mut demands: Vec<Vec<(i64, u32)>> =
                    vec![Vec::with_capacity(ng); model.resource_classes.len()];
                for (gi, g) in structure.groups.iter().enumerate() {
                    let mut per_class = vec![0i64; model.resource_classes.len()];
                    if let Some(&v0) = g.options.first() {
                        for &(ci, coef) in &knap[v0.idx()] {
                            let k = class_of[ci as usize];
                            if k != u32::MAX {
                                per_class[k as usize] = coef;
                            }
                        }
                    }
                    for (k, &d) in per_class.iter().enumerate() {
                        demands[k].push((d, gi as u32));
                    }
                }
                for (k, class) in model.resource_classes.iter().enumerate() {
                    let mut ds = std::mem::take(&mut demands[k]);
                    ds.sort_unstable();
                    cap_classes.push(CapClass {
                        cons: class.cons.clone(),
                        demands: ds,
                    });
                }
            }
        }

        let mut s = Searcher {
            model,
            structure,
            obj,
            config,
            prop,
            order,
            group_true: vec![0; ng],
            group_open: structure.groups.iter().map(|g| g.options.len() as u32).collect(),
            group_contrib: vec![0; ng],
            potential: 0,
            fixed_obj: 0,
            knap,
            knap_rhs,
            cap_classes,
            cap_weight,
            all_occ,
            cons_rhs: model.constraints.iter().map(|c| c.rhs).collect(),
            cons_op: model.constraints.iter().map(|c| c.op).collect(),
            best: None,
            best_val: i64::MIN,
            root_ub: 0,
            deadline,
            timed_out: false,
            cancelled: false,
            shared,
            floor: shared.map_or(i64::MIN, |s| s.floor()),
            decisions: 0,
            // First poll early (rate calibration + tiny-window safety);
            // the adaptive schedule takes over from there.
            next_poll: config.check_interval.clamp(1, MIN_POLL_INTERVAL),
            // detlint: allow(wall-clock) — deadline-poll rate calibration anchor
            last_poll: Instant::now(),
            last_poll_decisions: 0,
            conflicts: 0,
            bound_prunes: 0,
            floor_prunes: 0,
            symmetry_skips: 0,
            max_depth: 0,
            probe,
            conflict_attr: if probe.enabled() {
                Some(vec![0; model.constraints.len()])
            } else {
                None
            },
            unattributed_conflicts: 0,
        };

        // Root propagation may already have fixed vars: sync from scratch.
        for gi in 0..ng {
            s.resync_group(gi);
        }
        s.fixed_obj = (0..nv)
            .filter(|&v| s.prop.value(VarId(v as u32)) == Some(true))
            .map(|v| s.obj[v])
            .sum();
        // `upper_bound` counts decided groups' chosen coefficients plus
        // undecided potentials — exactly fixed_obj + potential, since every
        // variable belongs to exactly one group after presolve.
        debug_assert_eq!(
            s.fixed_obj + s.potential,
            upper_bound(&s.prop, s.structure, s.obj)
        );
        s.root_ub = s.ub(); // includes the capacity bound when available
        Some(s)
    }

    /// Fix some variables before search (LNS). Returns false on conflict.
    pub(super) fn preassign(&mut self, fixes: &[(VarId, bool)]) -> bool {
        let mark = self.prop.trail_len();
        self.prop.push_level();
        for &(v, val) in fixes {
            if !self.prop.decide(v, val) {
                return false;
            }
        }
        self.sync_from(mark);
        true
    }

    fn decided(&self, gi: usize) -> bool {
        self.group_true[gi] > 0 || self.group_open[gi] == 0
    }

    /// Recompute one group's open count and bound contribution.
    fn resync_group(&mut self, gi: usize) {
        let g = &self.structure.groups[gi];
        let mut open = 0u32;
        let mut truecnt = 0u32;
        let mut best_open = 0i64;
        for &v in &g.options {
            match self.prop.value(v) {
                None => {
                    open += 1;
                    best_open = best_open.max(self.obj[v.idx()]);
                }
                Some(true) => truecnt += 1,
                Some(false) => {}
            }
        }
        self.group_true[gi] = truecnt;
        self.group_open[gi] = open;
        let contrib = if truecnt > 0 || open == 0 { 0 } else { best_open.max(0) };
        self.potential += contrib - self.group_contrib[gi];
        self.group_contrib[gi] = contrib;
    }

    /// Incorporate every assignment made since `mark` into the
    /// objective bookkeeping.
    fn sync_from(&mut self, mark: usize) {
        let mut touched: Vec<u32> = Vec::new();
        // First pass: fixed_obj from newly-true vars.
        for &v in self.prop.trail_since(mark) {
            let gi = self.structure.var_group[v as usize];
            if self.prop.value(VarId(v)) == Some(true) {
                self.fixed_obj += self.obj[v as usize];
            }
            touched.push(gi);
        }
        touched.sort_unstable();
        touched.dedup();
        for gi in touched {
            self.resync_group(gi as usize);
        }
    }

    /// Undo one decision level, reversing bookkeeping.
    fn undo_to(&mut self, mark: usize) {
        let mut touched: Vec<u32> = Vec::new();
        for &v in self.prop.trail_since(mark) {
            if self.prop.value(VarId(v)) == Some(true) {
                self.fixed_obj -= self.obj[v as usize];
            }
            touched.push(self.structure.var_group[v as usize]);
        }
        self.prop.pop_level();
        touched.sort_unstable();
        touched.dedup();
        for gi in touched {
            self.resync_group(gi as usize);
        }
    }

    /// Aggregate fractional-capacity bound: across each resource class,
    /// at most k more groups fit, where k counts the smallest open-group
    /// demands that fit in the class's total residual capacity. Admissible
    /// because aggregation over nodes only relaxes the packing.
    fn cap_bound(&self) -> i64 {
        let mut k_min = i64::MAX;
        for class in &self.cap_classes {
            let mut residual: i64 = class
                .cons
                .iter()
                .map(|&ci| self.knap_rhs[ci as usize] - self.prop.cons_fixed(ci as usize))
                .sum();
            let mut k = 0i64;
            for &(d, gi) in &class.demands {
                let gi = gi as usize;
                if self.group_true[gi] > 0 || self.group_open[gi] == 0 {
                    continue; // decided: already in fixed_obj / unplaceable
                }
                if d > residual {
                    break; // demands ascend: nothing further fits
                }
                residual -= d;
                k += 1;
            }
            k_min = k_min.min(k);
        }
        if k_min == i64::MAX {
            i64::MAX
        } else {
            k_min.saturating_mul(self.cap_weight)
        }
    }

    #[inline]
    fn ub(&self) -> i64 {
        let mut pot = self.potential;
        if !self.cap_classes.is_empty() {
            pot = pot.min(self.cap_bound());
        }
        self.fixed_obj + pot
    }

    /// Count a decision and occasionally check the wall clock. The poll
    /// interval *adapts* to the measured decision rate: it backs off
    /// while decisions are cheap (an `Instant::now()` every 64 trivial
    /// decisions is pure overhead) and tightens as the deadline nears,
    /// so even a 30 ms window on a propagation-heavy instance is
    /// overshot by at most a few decisions, not by a fixed burst.
    /// Shared-race bookkeeping (floor refresh, cancellation) piggybacks
    /// on the same schedule.
    fn poll_deadline(&mut self) -> bool {
        self.decisions += 1;
        if self.decisions < self.next_poll {
            return self.timed_out;
        }
        if let Some(shared) = self.shared {
            if shared.is_cancelled() {
                self.cancelled = true;
                self.timed_out = true;
                return true;
            }
            self.floor = self.floor.max(shared.floor());
        }
        // detlint: allow(wall-clock) — the adaptive deadline poll itself
        let now = Instant::now();
        let remaining = self.deadline.remaining_from(now);
        if remaining.is_zero() {
            self.timed_out = true;
            return true;
        }
        // Seconds per decision since the last poll (floored so the
        // division below stays finite on coarse clocks).
        let span = (self.decisions - self.last_poll_decisions).max(1);
        let per_decision =
            (now.duration_since(self.last_poll).as_secs_f64() / span as f64).max(1e-9);
        // Aim the next poll at ~1/8 of the remaining window, capped at
        // 1 ms so long-deadline racers still notice cancellation fast.
        let target_s = (remaining.as_secs_f64() / 8.0).clamp(20e-6, 1e-3);
        let interval =
            ((target_s / per_decision) as u64).clamp(MIN_POLL_INTERVAL, MAX_POLL_INTERVAL);
        self.last_poll = now;
        self.last_poll_decisions = self.decisions;
        self.next_poll = self.decisions + interval;
        false
    }

    fn record_leaf(&mut self) {
        let val = self.fixed_obj;
        if val > self.best_val {
            self.best_val = val;
            let snap = self.prop.snapshot();
            debug_assert!(self.model.feasible(&snap), "leaf violates constraints");
            self.best = Some(snap);
            if let Some(shared) = self.shared {
                shared.publish(val);
            }
            // Optimality-gap timeline: decision-indexed (never wall
            // clock), so a completing search yields the same samples on
            // every run regardless of thread count or machine speed.
            self.probe.gap(self.decisions, val, self.root_ub);
        }
    }

    /// Attribute the conflict just returned by the propagator (no-op
    /// when the probe is off).
    #[inline]
    fn note_conflict(&mut self) {
        if let Some(attr) = &mut self.conflict_attr {
            match self.prop.last_conflict() {
                Some(ci) => attr[ci] += 1,
                None => self.unattributed_conflicts += 1,
            }
        }
    }

    /// Best-fit key: total normalised residual slack after placing `v`
    /// (lower = tighter = preferred).
    fn best_fit_key(&self, v: VarId) -> f64 {
        let mut key = 0.0;
        for &(ci, coef) in &self.knap[v.idx()] {
            let rhs = self.knap_rhs[ci as usize];
            let slack = rhs - self.prop.cons_fixed(ci as usize) - coef;
            key += slack as f64 / (rhs.max(1)) as f64;
        }
        key
    }

    /// Symmetry signature of option `v` under the current residual state.
    fn signature(&self, v: VarId) -> Vec<(i64, i64, i64, u8)> {
        let mut sig: Vec<(i64, i64, i64, u8)> = self.all_occ[v.idx()]
            .iter()
            .map(|&(ci, coef)| {
                let c = ci as usize;
                (
                    coef,
                    self.cons_rhs[c] - self.prop.cons_fixed(c),
                    self.cons_rhs[c],
                    match self.cons_op[c] {
                        CmpOp::Le => 0,
                        CmpOp::Ge => 1,
                        CmpOp::Eq => 2,
                    },
                )
            })
            .collect();
        sig.sort_unstable();
        sig
    }

    pub(super) fn dfs(&mut self, order_pos: usize, depth: u32) {
        if self.timed_out {
            return;
        }
        self.max_depth = self.max_depth.max(depth);

        // Bound prune — against the local incumbent once one exists, and
        // *strictly* against the shared race floor. Strictness is what
        // keeps portfolio racers deterministic: a subtree that could tie
        // the global best is never skipped, so a completing racer still
        // reports the same first-in-DFS-order optimum it finds alone.
        if self.config.use_bound && (self.best.is_some() || self.floor > i64::MIN) {
            let ub = self.ub();
            let local_cut = self.best.is_some() && ub <= self.best_val;
            if local_cut || ub < self.floor {
                if local_cut {
                    self.bound_prunes += 1;
                } else {
                    // Only the shared floor cut this subtree: credit the
                    // sibling racer whose published incumbent saved the work.
                    self.floor_prunes += 1;
                }
                return;
            }
        }

        // Advance to the next undecided group.
        let mut pos = order_pos;
        let gi = loop {
            match self.order.get(pos) {
                None => {
                    self.record_leaf();
                    return;
                }
                Some(&gi) if !self.decided(gi as usize) => break gi as usize,
                Some(_) => pos += 1,
            }
        };

        // Candidate options, ordered.
        let options = &self.structure.groups[gi].options;
        let mut cands: Vec<VarId> = options
            .iter()
            .copied()
            .filter(|&v| self.prop.is_unknown(v))
            .collect();
        let hinted = |v: VarId| -> bool {
            self.config.use_hints && self.model.hints[v.idx()] == Some(true)
        };
        if self.config.use_best_fit {
            let mut keyed: Vec<(bool, f64, VarId)> = cands
                .iter()
                .map(|&v| (!hinted(v), self.best_fit_key(v), v))
                .collect();
            keyed.sort_by(|a, b| a.0.cmp(&b.0).then(key_order(a.1, b.1, true)).then(a.2.cmp(&b.2)));
            cands = keyed.into_iter().map(|(_, _, v)| v).collect();
        } else if self.config.use_hints {
            cands.sort_by_key(|&v| (!hinted(v), v));
        }

        let mut seen_sigs: Vec<Vec<(i64, i64, i64, u8)>> = Vec::new();
        for v in cands {
            if self.timed_out {
                return;
            }
            if !self.prop.is_unknown(v) {
                continue; // an earlier sibling's failure propagation fixed it
            }
            if self.config.use_symmetry {
                let sig = self.signature(v);
                if seen_sigs.iter().any(|s| *s == sig) {
                    self.symmetry_skips += 1;
                    continue;
                }
                seen_sigs.push(sig);
            }
            if self.poll_deadline() {
                return;
            }
            let mark = self.prop.trail_len();
            self.prop.push_level();
            if self.prop.decide(v, true) {
                self.sync_from(mark);
                self.dfs(pos, depth + 1);
                self.undo_to(mark);
            } else {
                self.conflicts += 1;
                self.note_conflict();
                self.prop.pop_level();
            }
            if self.best_val >= self.root_ub && self.best.is_some() {
                return; // incumbent meets the root bound: optimal
            }
        }

        // "Place nowhere" branch: all remaining options false.
        if self.timed_out {
            return;
        }
        if self.poll_deadline() {
            return;
        }
        let mark = self.prop.trail_len();
        self.prop.push_level();
        let mut ok = true;
        for &v in &self.structure.groups[gi].options {
            if self.prop.is_unknown(v) {
                if !self.prop.decide(v, false) {
                    ok = false;
                    break;
                }
            }
        }
        if ok {
            self.sync_from(mark);
            self.dfs(pos, depth + 1);
            self.undo_to(mark);
        } else {
            self.conflicts += 1;
            self.note_conflict();
            self.prop.pop_level();
        }
    }

    /// Flush accumulated effort to the probe, mapping constraint indices
    /// to provenance slugs. Call once per solve, after the DFS; a no-op
    /// when the probe is off (every `attr` drops zero counts too, so
    /// untouched buckets never appear in the profile).
    pub(super) fn flush_probe(&self) {
        if !self.probe.enabled() {
            return;
        }
        if let Some(per) = self.prop.per_cons_propagations() {
            for (ci, &n) in per.iter().enumerate() {
                self.probe
                    .attr(self.model.constraint_provenance(ci), "propagations", n);
            }
        }
        if let Some(attr) = &self.conflict_attr {
            for (ci, &n) in attr.iter().enumerate() {
                self.probe
                    .attr(self.model.constraint_provenance(ci), "conflicts", n);
            }
        }
        self.probe
            .attr(UNTAGGED_PROVENANCE, "conflicts", self.unattributed_conflicts);
        self.probe.attr("search", "decisions", self.decisions);
        self.probe.attr("search:bound", "prunes", self.bound_prunes);
        self.probe.attr("search:floor", "prunes", self.floor_prunes);
        self.probe
            .attr("search:symmetry", "skips", self.symmetry_skips);
    }

    pub(super) fn drain_stats(&self, stats: &mut SearchStats) {
        stats.decisions += self.decisions;
        stats.propagations += self.prop.propagations;
        stats.conflicts += self.conflicts;
        stats.bound_prunes += self.bound_prunes;
        stats.floor_prunes += self.floor_prunes;
        stats.symmetry_skips += self.symmetry_skips;
        stats.max_depth = stats.max_depth.max(self.max_depth);
    }
}

/// Total order over float branching keys: ascending when `ascending`,
/// descending otherwise. `f64::total_cmp`, not `partial_cmp().unwrap()`:
/// a NaN key — impossible today, every difficulty/best-fit denominator
/// is clamped ≥ 1 — would still yield one deterministic branching order
/// instead of a panic mid-search (the NaN family PR 4 fixed in
/// `util/stats.rs`).
fn key_order(a: f64, b: f64, ascending: bool) -> std::cmp::Ordering {
    if ascending {
        a.total_cmp(&b)
    } else {
        b.total_cmp(&a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SolverConfig {
        SolverConfig::default()
    }

    #[test]
    fn branching_key_order_is_total_under_nan() {
        use std::cmp::Ordering::{Equal, Greater, Less};
        // Ascending: NaN ranks above every finite/infinite value.
        assert_eq!(key_order(f64::NAN, f64::INFINITY, true), Greater);
        assert_eq!(key_order(1.0, f64::NAN, true), Less);
        assert_eq!(key_order(f64::NAN, f64::NAN, true), Equal);
        // Descending flips consistently.
        assert_eq!(key_order(f64::NAN, 1.0, false), Less);
        assert_eq!(key_order(2.0, 1.0, false), Less);
        assert_eq!(key_order(1.0, 2.0, false), Greater);
    }

    #[test]
    fn nan_keys_sort_without_panicking() {
        // The regression PR 4's stats.rs fix guards against, applied to
        // the branching comparators: a NaN among the keys must produce
        // a deterministic order, never a panic.
        let mut keys = vec![1.0, f64::NAN, 0.5, f64::INFINITY, -0.0, 0.0, f64::NAN];
        keys.sort_by(|a, b| key_order(*a, *b, true));
        assert_eq!(keys[0], -0.0);
        assert!(keys[5].is_nan() && keys[6].is_nan());
        keys.sort_by(|a, b| key_order(*a, *b, false));
        assert!(keys[0].is_nan() && keys[1].is_nan());
        assert_eq!(keys[6], -0.0);
    }

    /// max x + y + z  s.t.  x+y<=1  → 2
    #[test]
    fn simple_maximum() {
        let mut m = Model::new();
        let x = m.new_var();
        let y = m.new_var();
        let z = m.new_var();
        m.add_le(LinearExpr::of([(x, 1), (y, 1)]), 1);
        let obj = LinearExpr::of([(x, 1), (y, 1), (z, 1)]);
        let sol = solve_max(&m, &obj, Deadline::unlimited(), &cfg());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert_eq!(sol.objective, 2);
        assert!(m.feasible(&sol.values));
    }

    /// Knapsack: items (w, v): (6,10) (5,8) (4,7) (3,5), cap 10 →
    /// best 17 = (6,10)+(4,7).
    #[test]
    fn knapsack_optimal() {
        let mut m = Model::new();
        let items = [(6, 10), (5, 8), (4, 7), (3, 5)];
        let vars = m.new_vars(items.len());
        m.add_le(
            LinearExpr::of(vars.iter().zip(&items).map(|(&v, &(w, _))| (v, w))),
            10,
        );
        let obj = LinearExpr::of(vars.iter().zip(&items).map(|(&v, &(_, val))| (v, val)));
        let sol = solve_max(&m, &obj, Deadline::unlimited(), &cfg());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert_eq!(sol.objective, 17);
    }

    /// The paper's Figure 1 as a packing model: 2 nodes ram 4096,
    /// pods ram {2048, 2048, 3072}: all three placeable.
    #[test]
    fn figure1_packing_all_three() {
        let mut m = Model::new();
        let pods = [2048i64, 2048, 3072];
        let mut vars = Vec::new();
        for _ in &pods {
            let xs = m.new_vars(2);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            vars.push(xs);
        }
        for node in 0..2 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&pods).map(|(xs, &r)| (xs[node], r))),
                4096,
            );
        }
        let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
        let sol = solve_max(&m, &obj, Deadline::unlimited(), &cfg());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert_eq!(sol.objective, 3);
    }

    #[test]
    fn infeasible_model_detected() {
        let mut m = Model::new();
        let x = m.new_var();
        m.add_ge(LinearExpr::of([(x, 1)]), 1);
        m.add_le(LinearExpr::of([(x, 1)]), 0);
        let sol = solve_max(&m, &LinearExpr::of([(x, 1)]), Deadline::unlimited(), &cfg());
        assert_eq!(sol.status, SolveStatus::Infeasible);
    }

    #[test]
    fn empty_model_trivially_optimal() {
        let m = Model::new();
        let sol = solve_max(&m, &LinearExpr::new(), Deadline::unlimited(), &cfg());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert_eq!(sol.objective, 0);
    }

    #[test]
    fn hints_steer_value_order() {
        // Two symmetric optima; the hint should pick which one we land on.
        let mut m = Model::new();
        let x = m.new_var();
        let y = m.new_var();
        m.add_le(LinearExpr::of([(x, 1), (y, 1)]), 1);
        m.hint(y, true);
        let obj = LinearExpr::of([(x, 1), (y, 1)]);
        let mut c = cfg();
        c.use_symmetry = false; // let the hint, not symmetry, decide
        let sol = solve_max(&m, &obj, Deadline::unlimited(), &c);
        assert_eq!(sol.objective, 1);
        assert!(sol.values[y.idx()]);
        assert!(!sol.values[x.idx()]);
    }

    #[test]
    fn negative_objective_prefers_none() {
        let mut m = Model::new();
        let x = m.new_var();
        let y = m.new_var();
        m.add_le(LinearExpr::of([(x, 1), (y, 1)]), 1);
        let obj = LinearExpr::of([(x, -3), (y, -5)]);
        let sol = solve_max(&m, &obj, Deadline::unlimited(), &cfg());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert_eq!(sol.objective, 0);
        assert!(!sol.values[x.idx()] && !sol.values[y.idx()]);
    }

    #[test]
    fn equality_lock_respected() {
        // Phase-locking pattern from Algorithm 1: fix Σx = 1 then maximize a
        // different metric.
        let mut m = Model::new();
        let x = m.new_var();
        let y = m.new_var();
        m.add_le(LinearExpr::of([(x, 1), (y, 1)]), 1);
        m.add_eq(LinearExpr::of([(x, 1), (y, 1)]), 1);
        let obj = LinearExpr::of([(x, 1), (y, 3)]);
        let sol = solve_max(&m, &obj, Deadline::unlimited(), &cfg());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert_eq!(sol.objective, 3);
        assert!(sol.values[y.idx()]);
    }

    #[test]
    fn bound_certificate_reported() {
        // Optimal: bound == objective.
        let mut m = Model::new();
        let x = m.new_var();
        let y = m.new_var();
        m.add_le(LinearExpr::of([(x, 1), (y, 1)]), 1);
        let sol = solve_max(&m, &LinearExpr::of([(x, 2), (y, 3)]), Deadline::unlimited(), &cfg());
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert_eq!(sol.bound, sol.objective);
    }

    #[test]
    fn easiest_first_branching_agrees_on_optimum() {
        let mut m = Model::new();
        let items = [(6, 10), (5, 8), (4, 7), (3, 5)];
        let vars = m.new_vars(items.len());
        m.add_le(
            LinearExpr::of(vars.iter().zip(&items).map(|(&v, &(w, _))| (v, w))),
            10,
        );
        let obj = LinearExpr::of(vars.iter().zip(&items).map(|(&v, &(_, val))| (v, val)));
        let rev = SolverConfig {
            branch_easiest_first: true,
            ..Default::default()
        };
        let sol = solve_max(&m, &obj, Deadline::unlimited(), &rev);
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert_eq!(sol.objective, 17);
    }

    #[test]
    fn shared_floor_does_not_change_a_completing_search() {
        // Publish a floor equal to the true optimum from a phantom rival;
        // the racer must still return the same optimal values it finds
        // alone (strict pruning keeps tie-valued subtrees reachable).
        let mut m = Model::new();
        let pods = [2048i64, 2048, 3072];
        let mut vars = Vec::new();
        for _ in &pods {
            let xs = m.new_vars(2);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            vars.push(xs);
        }
        for node in 0..2 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&pods).map(|(xs, &r)| (xs[node], r))),
                4096,
            );
        }
        let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
        let solo = solve_max(&m, &obj, Deadline::unlimited(), &cfg());
        assert_eq!(solo.status, SolveStatus::Optimal);

        let shared = SharedIncumbent::new();
        shared.publish(solo.objective);
        let raced = solve_max_with(&m, &obj, Deadline::unlimited(), &cfg(), Some(&shared));
        assert_eq!(raced.status, SolveStatus::Optimal);
        assert_eq!(raced.objective, solo.objective);
        assert_eq!(raced.values, solo.values);
    }

    #[test]
    fn cancellation_stops_the_search() {
        // A pre-cancelled handle must stop the racer at its first poll
        // and report Unknown (or whatever incumbent it managed) quickly.
        let mut m = Model::new();
        let mut vars = Vec::new();
        let demands: Vec<i64> = (0..30).map(|i| 100 + (i * 37) % 400).collect();
        for _ in &demands {
            let xs = m.new_vars(6);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            vars.push(xs);
        }
        for node in 0..6 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&demands).map(|(xs, &d)| (xs[node], d))),
                1200,
            );
        }
        let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
        let shared = SharedIncumbent::new();
        shared.cancel();
        let t = std::time::Instant::now();
        let sol = solve_max_with(
            &m,
            &obj,
            Deadline::after(std::time::Duration::from_secs(30)),
            &cfg(),
            Some(&shared),
        );
        // Must return far inside the 30 s deadline (first poll), and any
        // incumbent it did record must still be a real solution.
        assert!(
            t.elapsed() < std::time::Duration::from_secs(5),
            "cancellation ignored for {:?}",
            t.elapsed()
        );
        if sol.status.has_solution() {
            assert!(m.feasible(&sol.values));
        }
    }

    #[test]
    fn probe_is_invisible_to_the_search_and_attributes_all_effort() {
        // A mixed instance with tagged provenance: figure-1 packing with
        // the rows labelled the way PackingModelBuilder labels them.
        let mut m = Model::new();
        let pods = [2048i64, 2048, 3072];
        let mut vars = Vec::new();
        for _ in &pods {
            let from = m.next_constraint_index();
            let xs = m.new_vars(2);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            m.tag_constraints(from, "placement");
            vars.push(xs);
        }
        let from = m.next_constraint_index();
        for node in 0..2 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&pods).map(|(xs, &r)| (xs[node], r))),
                4096,
            );
        }
        m.tag_constraints(from, "capacity:ram");
        let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));

        let off = solve_max(&m, &obj, Deadline::unlimited(), &cfg());
        let probe = Probe::armed();
        let probed =
            solve_max_probed(&m, &obj, Deadline::unlimited(), &cfg(), None, &probe);

        // Identical answer AND identical search trajectory.
        assert_eq!(probed.status, off.status);
        assert_eq!(probed.objective, off.objective);
        assert_eq!(probed.values, off.values);
        assert_eq!(probed.bound, off.bound);
        assert_eq!(probed.stats.decisions, off.stats.decisions);
        assert_eq!(probed.stats.propagations, off.stats.propagations);
        assert_eq!(probed.stats.conflicts, off.stats.conflicts);

        // Every propagation/conflict/decision lands in some bucket.
        let eff = probe.module_effort();
        let sum = |kind: &str| -> u64 {
            eff.iter().filter(|(_, k, _)| *k == kind).map(|&(_, _, n)| n).sum()
        };
        assert_eq!(sum("propagations"), probed.stats.propagations);
        assert_eq!(sum("conflicts"), probed.stats.conflicts);
        assert_eq!(sum("decisions"), probed.stats.decisions);
        // Attribution reaches the provenance slugs, not just search:*.
        assert!(eff.iter().any(|(s, k, _)| s == "placement" && *k == "propagations"));
        assert!(eff.iter().any(|(s, k, _)| s == "capacity:ram" && *k == "propagations"));
        // Optimal solve: the gap timeline ends with incumbent == bound.
        let gaps = probe.gap_samples();
        assert!(!gaps.is_empty());
        let last = gaps.last().unwrap();
        assert_eq!(last.incumbent, probed.objective);
        assert!(last.incumbent <= last.bound);
    }

    #[test]
    fn anytime_feasible_under_tiny_deadline() {
        // Large-ish packing; a microscopic deadline must still yield
        // Feasible (or Optimal if search finishes) — never a panic.
        let mut m = Model::new();
        let mut vars = Vec::new();
        let demands: Vec<i64> = (0..40).map(|i| 100 + (i * 37) % 400).collect();
        for _ in &demands {
            let xs = m.new_vars(8);
            m.add_le(LinearExpr::of(xs.iter().map(|&v| (v, 1))), 1);
            vars.push(xs);
        }
        for node in 0..8 {
            m.add_le(
                LinearExpr::of(vars.iter().zip(&demands).map(|(xs, &d)| (xs[node], d))),
                1200,
            );
        }
        let obj = LinearExpr::of(vars.iter().flatten().map(|&v| (v, 1)));
        let sol = solve_max(
            &m,
            &obj,
            Deadline::after(std::time::Duration::from_millis(30)),
            &cfg(),
        );
        assert!(sol.status.has_solution());
        assert!(m.feasible(&sol.values));
    }
}
