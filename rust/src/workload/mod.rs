//! Workload generation and datasets (paper §Evaluation).
//!
//! "We generate a set of pod requests with configurable a) number of
//! nodes, b) average number of pods per node, c) workload ratio between
//! the total amount of resources in the cluster and the ones needed by
//! the pods, and d) maximal amount of pods' priorities." Pods get random
//! CPU/RAM in `[100, 1000]`, arrive as ReplicaSets of 1–4 replicas, and
//! node capacities are derived from total demand and the usage ratio
//! (identical nodes, "to reflect typical cloud deployments").

pub mod churn;
pub mod dataset;
pub mod generator;
pub mod scenarios;

pub use churn::{ChurnParams, ChurnTrace, ChurnTraceGenerator, TraceOp};
pub use generator::{GenParams, Instance};
pub use scenarios::ConstraintProfile;
