//! Constraint-rich scenario families for the workload generator.
//!
//! The paper's evaluation grid varies cluster size, pods-per-node,
//! priority tiers, and usage; a [`ConstraintProfile`] adds a fifth axis:
//! which scheduling-constraint family the generated cell exercises.
//! Profiles decorate the paper's base distribution — they never change
//! how many pods/ReplicaSets are drawn or their resource requests, and
//! [`ConstraintProfile::None`] draws nothing at all, so unconstrained
//! generation stays byte-identical to the seed generator.

use crate::cluster::{Node, ReplicaSet, Taint, Toleration};
use crate::util::rng::Rng;

/// Which constraint family a generated scenario cell exercises.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ConstraintProfile {
    /// The paper's plain resource-packing workload.
    #[default]
    None,
    /// ~¼ of nodes tainted `dedicated=batch:NoSchedule`; ~½ of
    /// ReplicaSets tolerate it.
    Taints,
    /// ~⅓ of ReplicaSets require their replicas on distinct nodes
    /// (self anti-affinity via an `app=<rs>` label).
    AntiAffinity,
    /// ~½ of ReplicaSets declare a max node skew of 1.
    Spread,
    /// ~½ of nodes offer `gpu` capacity; ~¼ of ReplicaSets request it.
    Extended,
    /// All of the above, layered.
    Mixed,
}

impl ConstraintProfile {
    /// Parse a `--constraints` CLI value.
    pub fn parse(s: &str) -> Option<ConstraintProfile> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(ConstraintProfile::None),
            "taints" => Some(ConstraintProfile::Taints),
            "anti-affinity" | "antiaffinity" => Some(ConstraintProfile::AntiAffinity),
            "spread" => Some(ConstraintProfile::Spread),
            "extended" | "gpu" => Some(ConstraintProfile::Extended),
            "mixed" => Some(ConstraintProfile::Mixed),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ConstraintProfile::None => "none",
            ConstraintProfile::Taints => "taints",
            ConstraintProfile::AntiAffinity => "anti-affinity",
            ConstraintProfile::Spread => "spread",
            ConstraintProfile::Extended => "extended",
            ConstraintProfile::Mixed => "mixed",
        }
    }

    /// Decorate one freshly drawn ReplicaSet. Draws from `rng` only for
    /// the families this profile enables, keeping `None` stream-neutral.
    pub fn decorate_replicaset(&self, mut rs: ReplicaSet, rng: &mut Rng) -> ReplicaSet {
        let (taints, anti, spread, extended) = self.axes();
        if taints && rng.chance(0.5) {
            rs = rs.with_toleration(Toleration::equal("dedicated", "batch"));
        }
        if anti && rng.chance(1.0 / 3.0) {
            let name = rs.name.clone();
            rs = rs.with_label("app", &name).with_anti_affinity("app", &name);
        }
        if spread && rng.chance(0.5) {
            rs = rs.with_spread(1);
        }
        if extended && rng.chance(0.25) {
            let amount = rng.range_i64(1, 2);
            rs = rs.with_extended("gpu", amount);
        }
        rs
    }

    /// Decorate the generated node pool (taints / extended capacities).
    pub fn decorate_nodes(&self, nodes: &mut [Node], rng: &mut Rng) {
        let (taints, _, _, extended) = self.axes();
        if taints {
            for n in nodes.iter_mut() {
                if rng.chance(0.25) {
                    n.taints.push(Taint::no_schedule("dedicated", "batch"));
                }
            }
        }
        if extended {
            for n in nodes.iter_mut() {
                if rng.chance(0.5) {
                    n.extended.push(("gpu".to_string(), 4));
                }
            }
        }
    }

    /// Which decoration axes this profile enables:
    /// (taints, anti-affinity, spread, extended).
    fn axes(&self) -> (bool, bool, bool, bool) {
        match self {
            ConstraintProfile::None => (false, false, false, false),
            ConstraintProfile::Taints => (true, false, false, false),
            ConstraintProfile::AntiAffinity => (false, true, false, false),
            ConstraintProfile::Spread => (false, false, true, false),
            ConstraintProfile::Extended => (false, false, false, true),
            ConstraintProfile::Mixed => (true, true, true, true),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{identical_nodes, Priority, Resources};

    #[test]
    fn parse_roundtrips_labels() {
        for p in [
            ConstraintProfile::None,
            ConstraintProfile::Taints,
            ConstraintProfile::AntiAffinity,
            ConstraintProfile::Spread,
            ConstraintProfile::Extended,
            ConstraintProfile::Mixed,
        ] {
            assert_eq!(ConstraintProfile::parse(p.label()), Some(p));
        }
        assert_eq!(ConstraintProfile::parse("bogus"), None);
    }

    #[test]
    fn none_profile_draws_nothing() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let rs = ReplicaSet::new(0, "rs-000", 2, Resources::new(100, 100), Priority(0));
        let out = ConstraintProfile::None.decorate_replicaset(rs, &mut a);
        assert!(out.tolerations.is_empty() && out.anti_affinity.is_empty());
        assert!(out.spread_max_skew.is_none() && out.extended.is_empty());
        let mut nodes = identical_nodes(4, Resources::new(100, 100));
        ConstraintProfile::None.decorate_nodes(&mut nodes, &mut a);
        // rng untouched: both streams still aligned
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn mixed_profile_decorates_eventually() {
        let mut rng = Rng::new(3);
        let mut any_tol = false;
        let mut any_anti = false;
        let mut any_spread = false;
        let mut any_gpu = false;
        for i in 0..64 {
            let rs = ReplicaSet::new(i, format!("rs-{i:03}"), 2, Resources::new(100, 100), Priority(0));
            let rs = ConstraintProfile::Mixed.decorate_replicaset(rs, &mut rng);
            any_tol |= !rs.tolerations.is_empty();
            any_anti |= !rs.anti_affinity.is_empty();
            any_spread |= rs.spread_max_skew.is_some();
            any_gpu |= !rs.extended.is_empty();
        }
        assert!(any_tol && any_anti && any_spread && any_gpu);
        let mut nodes = identical_nodes(32, Resources::new(100, 100));
        ConstraintProfile::Mixed.decorate_nodes(&mut nodes, &mut rng);
        assert!(nodes.iter().any(|n| !n.taints.is_empty()));
        assert!(nodes.iter().any(|n| !n.extended.is_empty()));
    }
}
