//! Churn traces: timed workload operations for the lifecycle simulator.
//!
//! The paper's generator produces one *static* queue of ReplicaSets; a
//! churn trace extends it along the time axis. The cluster starts with
//! the paper-distribution workload at `t = 0`, then a seeded operation
//! stream deploys new ReplicaSets, scales existing ones, drains nodes,
//! and joins fresh ones until the horizon. Every pod carries a lifetime,
//! so the live set rises and falls — the fragmentation regime the paper's
//! one-shot evaluation cannot reach.
//!
//! Traces are pure data: the same `(ChurnParams, seed)` pair always
//! yields the identical `ops` vector, which is what makes timeline
//! replay (and the byte-identical event-log property) possible.

use crate::autoscaler::NodePool;
use crate::cluster::{Node, Priority, ReplicaSet, Resources};
use crate::util::rng::Rng;

use super::generator::{GenParams, Instance};
use super::scenarios::ConstraintProfile;

/// Parameters of a churn trace (one cell of a future churn grid).
#[derive(Clone, Copy, Debug)]
pub struct ChurnParams {
    /// Initial cluster + workload shape (the paper's generator cell).
    pub base: GenParams,
    /// Simulated horizon, in milliseconds of virtual time.
    pub horizon_ms: u64,
    /// Mean gap between workload operations (uniform in [½·m, 1½·m]).
    pub mean_arrival_ms: u64,
    /// Mean pod lifetime (uniform in [½·m, 1½·m]); pods outliving the
    /// horizon simply never complete.
    pub mean_lifetime_ms: u64,
    /// Probability an operation scales an existing ReplicaSet.
    pub scale_chance: f64,
    /// Probability an operation drains a (random ready) node.
    pub drain_chance: f64,
    /// Probability an operation joins a fresh node.
    pub join_chance: f64,
}

impl ChurnParams {
    /// Sensible defaults around a base cell: ~50 operations across a
    /// 30-second horizon with mild node churn.
    pub fn for_cluster(base: GenParams) -> ChurnParams {
        ChurnParams {
            base,
            horizon_ms: 30_000,
            mean_arrival_ms: 600,
            mean_lifetime_ms: 8_000,
            scale_chance: 0.25,
            drain_chance: 0.04,
            join_chance: 0.04,
        }
    }
}

/// One timed workload operation.
#[derive(Clone, Debug)]
pub enum TraceOp {
    /// Deploy a new ReplicaSet; `lifetimes_ms[i]` is replica i's lifetime.
    Deploy {
        rs: ReplicaSet,
        lifetimes_ms: Vec<u64>,
    },
    /// Scale ReplicaSet `rs` by `delta` replicas (new replicas get the
    /// given lifetimes; negative deltas terminate the newest replicas).
    Scale {
        rs: u32,
        delta: i32,
        lifetimes_ms: Vec<u64>,
    },
    /// Drain node `node` (cordon + evict) — the trace generator only
    /// targets nodes it believes are still ready.
    Drain { node: u32 },
    /// Join a fresh node. `pool` carries the node-pool decorations
    /// (labels, taints, extended capacities) on heterogeneous traces;
    /// `None` joins a plain node of `capacity` — the paper's identical
    /// fleet, byte-identical to the pre-pool trace format.
    Join {
        capacity: Resources,
        pool: Option<NodePool>,
    },
}

/// A complete churn trace: initial nodes plus the timed operation list
/// (non-decreasing in time; the initial workload is deployed at t = 0).
#[derive(Clone, Debug)]
pub struct ChurnTrace {
    pub params: ChurnParams,
    pub seed: u64,
    /// Worker nodes at t = 0 (identical from the paper's generator, or
    /// a heterogeneous pool mix).
    pub nodes: Vec<Node>,
    /// The "standard node" capacity pool scales derive from (see
    /// [`Instance::generate_pooled`]); `nodes[0].capacity` on identical
    /// fleets.
    pub reference_capacity: Resources,
    /// Highest priority value in the trace (`tiers - 1`).
    pub p_max: u32,
    pub ops: Vec<(u64, TraceOp)>,
}

impl ChurnTrace {
    /// Number of operations of each kind: (deploys, scales, drains, joins).
    pub fn op_counts(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for (_, op) in &self.ops {
            match op {
                TraceOp::Deploy { .. } => c.0 += 1,
                TraceOp::Scale { .. } => c.1 += 1,
                TraceOp::Drain { .. } => c.2 += 1,
                TraceOp::Join { .. } => c.3 += 1,
            }
        }
        c
    }

    /// Total pods the trace can create (deploys + positive scale deltas).
    pub fn max_pods(&self) -> usize {
        self.ops
            .iter()
            .map(|(_, op)| match op {
                TraceOp::Deploy { rs, .. } => rs.replicas as usize,
                TraceOp::Scale { delta, .. } => (*delta).max(0) as usize,
                _ => 0,
            })
            .sum()
    }
}

/// Seeded generator: `(params, seed, profile) -> ChurnTrace`,
/// deterministically. The constraint profile decorates the initial
/// instance (nodes included) and every ReplicaSet the operation stream
/// deploys; joined nodes arrive undecorated (a fresh node has no taints
/// or device plugins yet). [`ConstraintProfile::None`] — the default —
/// consumes no extra randomness, so existing traces replay bit-for-bit.
#[derive(Clone, Debug)]
pub struct ChurnTraceGenerator {
    pub params: ChurnParams,
    pub seed: u64,
    pub profile: ConstraintProfile,
    /// Heterogeneous node-pool mix: the initial fleet cycles it (see
    /// [`Instance::generate_pooled`]) and joined nodes continue the
    /// cycle. Empty = the paper's identical fleet; pools draw no
    /// randomness, so non-pooled traces replay bit-for-bit.
    pub pools: Vec<NodePool>,
}

impl ChurnTraceGenerator {
    pub fn new(params: ChurnParams, seed: u64) -> Self {
        ChurnTraceGenerator {
            params,
            seed,
            profile: ConstraintProfile::None,
            pools: Vec::new(),
        }
    }

    /// Select the constraint scenario family for this trace.
    pub fn with_profile(mut self, profile: ConstraintProfile) -> Self {
        self.profile = profile;
        self
    }

    /// Select the heterogeneous node-pool mix for this trace.
    pub fn with_pools(mut self, pools: Vec<NodePool>) -> Self {
        self.pools = pools;
        self
    }

    pub fn generate(&self) -> ChurnTrace {
        let params = self.params;
        let mut rng = Rng::new(self.seed);

        // Initial cluster + workload from the paper's generator, deployed
        // as t = 0 operations so every pod flows through the same path.
        let inst =
            Instance::generate_pooled(params.base, rng.next_u64(), self.profile, &self.pools);
        let mut ops: Vec<(u64, TraceOp)> = Vec::new();
        for rs in &inst.replicasets {
            let lifetimes = sample_lifetimes(&mut rng, rs.replicas, params.mean_lifetime_ms);
            ops.push((
                0,
                TraceOp::Deploy {
                    rs: rs.clone(),
                    lifetimes_ms: lifetimes,
                },
            ));
        }

        // Operation stream until the horizon. `ready` mirrors the node
        // pool the simulator will maintain (joins append dense ids).
        let mut live_rs: Vec<u32> = inst.replicasets.iter().map(|r| r.id).collect();
        let mut next_rs = inst.replicasets.len() as u32;
        let mut ready: Vec<u32> = (0..params.base.nodes as u32).collect();
        let mut next_node = params.base.nodes as u32;
        let mut t = 0u64;

        loop {
            t += jittered(&mut rng, params.mean_arrival_ms);
            if t > params.horizon_ms {
                break;
            }
            let roll = rng.f64();
            if roll < params.drain_chance && ready.len() > 1 {
                let pick = rng.below(ready.len() as u64) as usize;
                let node = ready.swap_remove(pick);
                ops.push((t, TraceOp::Drain { node }));
            } else if roll < params.drain_chance + params.join_chance {
                // Joined nodes continue the pool cycle the initial fleet
                // started (node ordinal mod mix length); identical
                // fleets join a clone of node 0, as before.
                let (capacity, pool) = if self.pools.is_empty() {
                    (inst.nodes[0].capacity, None)
                } else {
                    let p = &self.pools[next_node as usize % self.pools.len()];
                    (p.capacity_for(inst.reference_capacity), Some(p.clone()))
                };
                ready.push(next_node);
                next_node += 1;
                ops.push((t, TraceOp::Join { capacity, pool }));
            } else if roll < params.drain_chance + params.join_chance + params.scale_chance
                && !live_rs.is_empty()
            {
                let rs = *rng.choice(&live_rs);
                let delta = if rng.chance(0.5) {
                    rng.range_i64(1, 3) as i32
                } else {
                    -(rng.range_i64(1, 2) as i32)
                };
                let lifetimes = if delta > 0 {
                    sample_lifetimes(&mut rng, delta as u32, params.mean_lifetime_ms)
                } else {
                    Vec::new()
                };
                ops.push((
                    t,
                    TraceOp::Scale {
                        rs,
                        delta,
                        lifetimes_ms: lifetimes,
                    },
                ));
            } else {
                // New ReplicaSet, same distribution as the paper's
                // generator: 1–4 replicas, CPU/RAM ~ U[100, 1000],
                // uniform priority.
                let replicas = rng.range_usize(1, 4) as u32;
                let req = Resources::new(rng.range_i64(100, 1000), rng.range_i64(100, 1000));
                let priority = Priority(rng.below(params.base.priority_tiers as u64) as u32);
                let rs = ReplicaSet::new(next_rs, format!("rs-{next_rs:03}"), replicas, req, priority);
                let rs = self.profile.decorate_replicaset(rs, &mut rng);
                live_rs.push(next_rs);
                next_rs += 1;
                let lifetimes = sample_lifetimes(&mut rng, replicas, params.mean_lifetime_ms);
                ops.push((
                    t,
                    TraceOp::Deploy {
                        rs,
                        lifetimes_ms: lifetimes,
                    },
                ));
            }
        }

        ChurnTrace {
            params,
            seed: self.seed,
            nodes: inst.nodes,
            reference_capacity: inst.reference_capacity,
            p_max: params.base.p_max(),
            ops,
        }
    }
}

/// Uniform in [½·mean, 1½·mean], never zero.
fn jittered(rng: &mut Rng, mean_ms: u64) -> u64 {
    let lo = (mean_ms / 2).max(1);
    let hi = (mean_ms * 3 / 2).max(lo + 1);
    rng.range_i64(lo as i64, hi as i64) as u64
}

fn sample_lifetimes(rng: &mut Rng, count: u32, mean_ms: u64) -> Vec<u64> {
    (0..count).map(|_| jittered(rng, mean_ms)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ChurnParams {
        ChurnParams::for_cluster(GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 0.95,
        })
    }

    #[test]
    fn deterministic_per_seed() {
        let a = ChurnTraceGenerator::new(params(), 42).generate();
        let b = ChurnTraceGenerator::new(params(), 42).generate();
        assert_eq!(format!("{:?}", a.ops), format!("{:?}", b.ops));
        assert_eq!(a.nodes.len(), b.nodes.len());
        let c = ChurnTraceGenerator::new(params(), 43).generate();
        assert_ne!(format!("{:?}", a.ops), format!("{:?}", c.ops));
    }

    #[test]
    fn times_non_decreasing_and_bounded() {
        let t = ChurnTraceGenerator::new(params(), 7).generate();
        let mut last = 0;
        for (at, _) in &t.ops {
            assert!(*at >= last);
            assert!(*at <= t.params.horizon_ms);
            last = *at;
        }
    }

    #[test]
    fn initial_workload_deployed_at_time_zero() {
        let t = ChurnTraceGenerator::new(params(), 9).generate();
        let initial: Vec<_> = t.ops.iter().take_while(|(at, _)| *at == 0).collect();
        assert!(!initial.is_empty());
        assert!(initial
            .iter()
            .all(|(_, op)| matches!(op, TraceOp::Deploy { .. })));
        // initial pods match the paper generator's pod budget
        let pods: usize = initial
            .iter()
            .map(|(_, op)| match op {
                TraceOp::Deploy { rs, .. } => rs.replicas as usize,
                _ => 0,
            })
            .sum();
        assert_eq!(pods, t.params.base.pod_count());
    }

    #[test]
    fn lifetimes_match_replica_counts() {
        let t = ChurnTraceGenerator::new(params(), 11).generate();
        for (_, op) in &t.ops {
            match op {
                TraceOp::Deploy { rs, lifetimes_ms } => {
                    assert_eq!(lifetimes_ms.len(), rs.replicas as usize);
                    assert!(lifetimes_ms.iter().all(|&l| l > 0));
                }
                TraceOp::Scale {
                    delta,
                    lifetimes_ms,
                    ..
                } => {
                    assert_eq!(lifetimes_ms.len(), (*delta).max(0) as usize);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn pooled_traces_cycle_the_mix_through_joins() {
        let pools = NodePool::parse_mix("small,large").unwrap();
        // High join chance so the trace reliably joins nodes.
        let mut p = params();
        p.join_chance = 0.5;
        p.drain_chance = 0.0;
        let t = ChurnTraceGenerator::new(p, 31)
            .with_pools(pools.clone())
            .generate();
        // initial fleet is heterogeneous
        assert_ne!(t.nodes[0].capacity, t.nodes[1].capacity);
        // joins carry pool decorations and continue the ordinal cycle
        let joins: Vec<(&Resources, &NodePool)> = t
            .ops
            .iter()
            .filter_map(|(_, op)| match op {
                TraceOp::Join { capacity, pool } => Some((capacity, pool.as_ref().unwrap())),
                _ => None,
            })
            .collect();
        assert!(!joins.is_empty(), "join chance 0.5 must join nodes");
        let mut ord = t.nodes.len();
        for (capacity, pool) in joins {
            assert_eq!(pool.name, pools[ord % pools.len()].name);
            assert_eq!(*capacity, pool.capacity_for(t.reference_capacity));
            ord += 1;
        }
        // and an unpooled trace still joins undecorated nodes
        let plain = ChurnTraceGenerator::new(p, 31).generate();
        for (_, op) in &plain.ops {
            if let TraceOp::Join { pool, .. } = op {
                assert!(pool.is_none());
            }
        }
    }

    #[test]
    fn churn_actually_churns() {
        // With the default knobs a 30s horizon must produce a healthy
        // operation mix (deploys always; usually some scales too).
        let t = ChurnTraceGenerator::new(params(), 5).generate();
        let (deploys, _scales, _drains, _joins) = t.op_counts();
        assert!(deploys >= 5, "too few deploys: {:?}", t.op_counts());
        assert!(t.ops.len() >= 20, "trace too short: {}", t.ops.len());
        assert!(t.max_pods() > t.params.base.pod_count());
    }
}
