//! The paper's random instance generator, extended with constraint-rich
//! scenario families (see [`ConstraintProfile`]) and heterogeneous
//! node-pool fleets (see [`NodePool`]) — the paper assumes identical
//! node capacities "to reflect typical cloud deployments", but real
//! clusters mix instance types, and the autoscaler benches need fleets
//! that do too.

use crate::autoscaler::NodePool;
use crate::cluster::{identical_nodes, Node, NodeId, Pod, Priority, ReplicaSet, Resources};
use crate::simulator::KwokSimulator;
use crate::util::rng::Rng;

use super::scenarios::ConstraintProfile;

/// Generation parameters (one cell of the paper's evaluation grid).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GenParams {
    /// Cluster size: 4, 8, 16 or 32 in the paper.
    pub nodes: usize,
    /// Average pods per node: 4 or 8.
    pub pods_per_node: usize,
    /// Number of priority tiers: 1, 2 or 4 (priorities 0..tiers).
    pub priority_tiers: u32,
    /// Target usage: pod demand / cluster capacity (0.90 … 1.05).
    pub usage: f64,
}

impl GenParams {
    pub fn pod_count(&self) -> usize {
        self.nodes * self.pods_per_node
    }

    /// Highest priority value (`p_max`); tiers = p_max + 1.
    pub fn p_max(&self) -> u32 {
        self.priority_tiers - 1
    }

    pub fn label(&self) -> String {
        format!(
            "n{}-ppn{}-pr{}-u{:.0}",
            self.nodes,
            self.pods_per_node,
            self.priority_tiers,
            self.usage * 100.0
        )
    }
}

/// One generated scheduling instance: ReplicaSets expanded into pods in
/// arrival order, plus the derived (identical) nodes.
#[derive(Clone, Debug)]
pub struct Instance {
    pub params: GenParams,
    pub seed: u64,
    /// Constraint scenario family this instance was decorated with.
    pub profile: ConstraintProfile,
    /// Node-pool mix the fleet was drawn from (empty = the paper's
    /// identical nodes).
    pub pools: Vec<NodePool>,
    /// The "standard node" capacity pool scales apply to — equals
    /// `nodes[0].capacity` on identical fleets; heterogeneous fleets and
    /// churn joins derive their per-pool capacities from it.
    pub reference_capacity: Resources,
    pub replicasets: Vec<ReplicaSet>,
    pub pods: Vec<Pod>,
    pub nodes: Vec<Node>,
}

impl Instance {
    /// Generate one instance from a seed, following the paper:
    /// ReplicaSets of 1–4 replicas with CPU/RAM ~ U[100, 1000] and a
    /// uniform random priority, generated until the pod budget is
    /// reached (the last set is truncated to hit the count exactly);
    /// then identical node capacities chosen so total pod demand equals
    /// `usage` × cluster capacity.
    pub fn generate(params: GenParams, seed: u64) -> Instance {
        Instance::generate_constrained(params, seed, ConstraintProfile::None)
    }

    /// Like [`Instance::generate`], additionally decorating ReplicaSets
    /// and nodes with a constraint scenario family. The base
    /// distribution (replica counts, requests, priorities, node
    /// capacities) is untouched, and `ConstraintProfile::None` consumes
    /// no extra randomness — so unconstrained generation is
    /// byte-identical to the paper's generator.
    pub fn generate_constrained(
        params: GenParams,
        seed: u64,
        profile: ConstraintProfile,
    ) -> Instance {
        Instance::generate_pooled(params, seed, profile, &[])
    }

    /// Like [`Instance::generate_constrained`], additionally drawing the
    /// fleet from a heterogeneous [`NodePool`] mix: node `i` takes pool
    /// `i mod pools.len()`, and the reference capacity is chosen so the
    /// *aggregate* fleet capacity still meets the `usage` ratio (the
    /// paper's derivation, generalised to non-uniform scales). The pod
    /// workload and all profile decorations are untouched, pools draw no
    /// randomness, and an empty mix is byte-identical to the paper's
    /// identical-capacity generator.
    pub fn generate_pooled(
        params: GenParams,
        seed: u64,
        profile: ConstraintProfile,
        pools: &[NodePool],
    ) -> Instance {
        let mut rng = Rng::new(seed);
        let budget = params.pod_count();
        let mut replicasets = Vec::new();
        let mut pods: Vec<Pod> = Vec::with_capacity(budget);
        let mut next_pod = 0u32;
        let mut rs_id = 0u32;

        while pods.len() < budget {
            let mut replicas = rng.range_usize(1, 4) as u32;
            replicas = replicas.min((budget - pods.len()) as u32);
            let req = Resources::new(rng.range_i64(100, 1000), rng.range_i64(100, 1000));
            let priority = Priority(rng.below(params.priority_tiers as u64) as u32);
            let rs = ReplicaSet::new(rs_id, format!("rs-{rs_id:03}"), replicas, req, priority);
            let rs = profile.decorate_replicaset(rs, &mut rng);
            pods.extend(rs.expand(&mut next_pod));
            replicasets.push(rs);
            rs_id += 1;
        }

        // Reference capacity from total demand and the usage ratio: the
        // fleet's total scale (in node-equivalents) replaces the plain
        // node count when pools are in play.
        let total: Resources = pods.iter().map(|p| p.request).sum();
        let (cap, mut nodes) = if pools.is_empty() {
            let cap = Resources::new(
                ((total.cpu as f64) / (params.usage * params.nodes as f64)).ceil() as i64,
                ((total.ram as f64) / (params.usage * params.nodes as f64)).ceil() as i64,
            );
            (cap, identical_nodes(params.nodes, cap))
        } else {
            let scale_sum: i64 = (0..params.nodes)
                .map(|i| pools[i % pools.len()].scale_milli)
                .sum();
            let denom = params.usage * (scale_sum as f64 / 1000.0);
            let cap = Resources::new(
                ((total.cpu as f64) / denom).ceil() as i64,
                ((total.ram as f64) / denom).ceil() as i64,
            );
            let nodes = (0..params.nodes)
                .map(|i| {
                    let mut n = pools[i % pools.len()].node_template(cap);
                    n.id = NodeId(i as u32);
                    n.name = format!("node-{i:03}");
                    n
                })
                .collect();
            (cap, nodes)
        };
        profile.decorate_nodes(&mut nodes, &mut rng);

        Instance {
            params,
            seed,
            profile,
            pools: pools.to_vec(),
            reference_capacity: cap,
            replicasets,
            pods,
            nodes,
        }
    }

    /// Generate the paper's *challenging* dataset: run the (deterministic)
    /// default scheduler and keep only instances it fails to fully place,
    /// taking the first `count` failures — "we discard the instances
    /// where KWOK successfully places all pods, selecting the first 100
    /// instances it fails to do so". Returns fewer if `max_attempts`
    /// seeds are exhausted (happens at low usage levels).
    pub fn generate_challenging(
        params: GenParams,
        count: usize,
        base_seed: u64,
        max_attempts: usize,
    ) -> Vec<Instance> {
        Instance::generate_challenging_constrained(
            params,
            count,
            base_seed,
            max_attempts,
            ConstraintProfile::None,
        )
    }

    /// [`Instance::generate_challenging`] over a constraint scenario
    /// family: kept instances are those the (constraint-aware) default
    /// scheduler fails to fully place.
    pub fn generate_challenging_constrained(
        params: GenParams,
        count: usize,
        base_seed: u64,
        max_attempts: usize,
        profile: ConstraintProfile,
    ) -> Vec<Instance> {
        Instance::generate_challenging_pooled(params, count, base_seed, max_attempts, profile, &[])
    }

    /// [`Instance::generate_challenging_constrained`] over a
    /// heterogeneous node-pool fleet: kept instances are those the
    /// default scheduler fails to fully place *on that mixed fleet*.
    pub fn generate_challenging_pooled(
        params: GenParams,
        count: usize,
        base_seed: u64,
        max_attempts: usize,
        profile: ConstraintProfile,
        pools: &[NodePool],
    ) -> Vec<Instance> {
        let mut out = Vec::with_capacity(count);
        let mut seed_rng = Rng::new(base_seed);
        for _ in 0..max_attempts {
            if out.len() >= count {
                break;
            }
            let inst = Instance::generate_pooled(params, seed_rng.next_u64(), profile, pools);
            let mut sim = KwokSimulator::new(params.p_max());
            let (_, res) = sim.run(inst.nodes.clone(), inst.pods.clone());
            if !res.all_placed {
                out.push(inst);
            }
        }
        out
    }

    /// Total resources requested by all pods.
    pub fn total_demand(&self) -> Resources {
        self.pods.iter().map(|p| p.request).sum()
    }

    /// Actual demand/capacity ratio achieved (≈ params.usage, slightly
    /// below due to capacity rounding up). Sums per-node capacities, so
    /// it holds for heterogeneous pool fleets too.
    pub fn actual_usage(&self) -> (f64, f64) {
        let d = self.total_demand();
        let c: Resources = self.nodes.iter().map(|n| n.capacity).sum();
        (d.cpu as f64 / c.cpu as f64, d.ram as f64 / c.ram as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> GenParams {
        GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 1.0,
        }
    }

    #[test]
    fn generates_exact_pod_count() {
        let inst = Instance::generate(params(), 42);
        assert_eq!(inst.pods.len(), 16);
        assert_eq!(inst.nodes.len(), 4);
        let total_rs: u32 = inst.replicasets.iter().map(|r| r.replicas).sum();
        assert_eq!(total_rs as usize, 16);
    }

    #[test]
    fn requests_in_paper_range() {
        let inst = Instance::generate(params(), 7);
        for p in &inst.pods {
            assert!((100..=1000).contains(&p.request.cpu), "{:?}", p.request);
            assert!((100..=1000).contains(&p.request.ram), "{:?}", p.request);
            assert!(p.priority.0 < 2);
        }
    }

    #[test]
    fn usage_ratio_approximately_met() {
        for seed in [1, 2, 3] {
            let inst = Instance::generate(
                GenParams {
                    usage: 0.95,
                    ..params()
                },
                seed,
            );
            let (cpu, ram) = inst.actual_usage();
            // capacity rounds up, so actual usage is slightly <= target
            assert!(cpu <= 0.95 + 1e-9 && cpu > 0.90, "cpu usage {cpu}");
            assert!(ram <= 0.95 + 1e-9 && ram > 0.90, "ram usage {ram}");
        }
    }

    #[test]
    fn nodes_identical_and_sorted() {
        let inst = Instance::generate(params(), 9);
        let cap = inst.nodes[0].capacity;
        for n in &inst.nodes {
            assert_eq!(n.capacity, cap);
        }
        for w in inst.nodes.windows(2) {
            assert!(w[0].name < w[1].name);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Instance::generate(params(), 1234);
        let b = Instance::generate(params(), 1234);
        assert_eq!(a.pods.len(), b.pods.len());
        for (x, y) in a.pods.iter().zip(&b.pods) {
            assert_eq!(x.request, y.request);
            assert_eq!(x.priority, y.priority);
        }
        let c = Instance::generate(params(), 1235);
        assert!(
            a.pods.iter().zip(&c.pods).any(|(x, y)| x.request != y.request),
            "different seeds should differ"
        );
    }

    #[test]
    fn replicas_share_template() {
        let inst = Instance::generate(params(), 5);
        for rs in &inst.replicasets {
            let members: Vec<_> = inst.pods.iter().filter(|p| p.owner == Some(rs.id)).collect();
            assert_eq!(members.len(), rs.replicas as usize);
            for m in members {
                assert_eq!(m.request, rs.template_request);
                assert_eq!(m.priority, rs.priority);
            }
        }
    }

    #[test]
    fn constrained_generation_keeps_base_distribution() {
        // Same seed, different profiles: identical replica counts,
        // requests, priorities, and node capacities — only decorations
        // differ.
        let plain = Instance::generate(params(), 11);
        let mixed = Instance::generate_constrained(params(), 11, ConstraintProfile::Mixed);
        assert_eq!(plain.pods.len(), mixed.pods.len());
        assert_eq!(plain.nodes[0].capacity, mixed.nodes[0].capacity);
        for (a, b) in plain.replicasets.iter().zip(&mixed.replicasets) {
            assert_eq!(a.replicas, b.replicas);
            assert_eq!(a.template_request, b.template_request);
            assert_eq!(a.priority, b.priority);
        }
        assert_eq!(mixed.profile, ConstraintProfile::Mixed);
        // and constrained generation is deterministic per seed
        let again = Instance::generate_constrained(params(), 11, ConstraintProfile::Mixed);
        for (a, b) in mixed.pods.iter().zip(&again.pods) {
            assert_eq!(a.tolerations, b.tolerations);
            assert_eq!(a.anti_affinity, b.anti_affinity);
            assert_eq!(a.spread_max_skew, b.spread_max_skew);
            assert_eq!(a.extended, b.extended);
        }
    }

    #[test]
    fn pooled_fleet_is_heterogeneous_and_keeps_the_workload() {
        let pools = NodePool::parse_mix("small,large").unwrap();
        let plain = Instance::generate(params(), 17);
        let pooled = Instance::generate_pooled(params(), 17, ConstraintProfile::None, &pools);
        // identical workload: pools never touch the pod stream
        assert_eq!(plain.pods.len(), pooled.pods.len());
        for (a, b) in plain.pods.iter().zip(&pooled.pods) {
            assert_eq!(a.request, b.request);
            assert_eq!(a.priority, b.priority);
        }
        // fleet alternates small/large around the reference capacity
        let reference = pooled.reference_capacity;
        assert_eq!(pooled.nodes.len(), 4);
        assert_eq!(pooled.nodes[0].capacity, NodePool::small().capacity_for(reference));
        assert_eq!(pooled.nodes[1].capacity, NodePool::large().capacity_for(reference));
        assert_ne!(pooled.nodes[0].capacity, pooled.nodes[1].capacity);
        // names stay canonical (sorted, dense) so joins keep working
        for (i, n) in pooled.nodes.iter().enumerate() {
            assert_eq!(n.name, format!("node-{i:03}"));
        }
        // aggregate capacity still meets the usage target (rounded up)
        let (cpu, ram) = pooled.actual_usage();
        assert!(cpu <= 1.0 + 1e-9 && cpu > 0.9, "cpu usage {cpu}");
        assert!(ram <= 1.0 + 1e-9 && ram > 0.9, "ram usage {ram}");
        // deterministic per (seed, mix)
        let again = Instance::generate_pooled(params(), 17, ConstraintProfile::None, &pools);
        assert_eq!(
            format!("{:?}", pooled.nodes),
            format!("{:?}", again.nodes)
        );
    }

    #[test]
    fn gpu_pool_decorates_extended_capacity() {
        let pools = NodePool::parse_mix("small,gpu").unwrap();
        let inst = Instance::generate_pooled(params(), 3, ConstraintProfile::None, &pools);
        assert_eq!(inst.nodes[1].extended_capacity("gpu"), 4);
        assert_eq!(inst.nodes[0].extended_capacity("gpu"), 0);
    }

    #[test]
    fn empty_pool_mix_is_byte_identical_to_the_paper_generator() {
        let plain = Instance::generate(params(), 23);
        let pooled = Instance::generate_pooled(params(), 23, ConstraintProfile::None, &[]);
        assert_eq!(format!("{:?}", plain.nodes), format!("{:?}", pooled.nodes));
        assert_eq!(plain.reference_capacity, plain.nodes[0].capacity);
        for (a, b) in plain.pods.iter().zip(&pooled.pods) {
            assert_eq!(a.request, b.request);
        }
    }

    #[test]
    fn challenging_instances_fail_kwok() {
        let insts = Instance::generate_challenging(
            GenParams {
                usage: 1.05,
                ..params()
            },
            5,
            99,
            200,
        );
        assert!(!insts.is_empty());
        for inst in &insts {
            let mut sim = KwokSimulator::new(inst.params.p_max());
            let (_, res) = sim.run(inst.nodes.clone(), inst.pods.clone());
            assert!(!res.all_placed);
        }
    }
}
