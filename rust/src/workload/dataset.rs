//! Dataset (de)serialization: instances ↔ JSON files.
//!
//! The benchmark harness persists generated datasets so experiment runs
//! are reproducible and compareable across solver configurations (the
//! paper fixes its 100 instances per parameter combination the same way).

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::autoscaler::NodePool;
use crate::cluster::{identical_nodes, Pod, Priority, ReplicaSet, Resources};
use crate::util::json::{parse, Json};

use super::generator::{GenParams, Instance};
use super::scenarios::ConstraintProfile;

/// Serialize one instance. Constraint decorations are recorded by
/// *profile name* — the generator is deterministic per `(params, seed,
/// profile)`, so the loader re-derives them exactly (see
/// [`instance_from_json`]). Node pools are recorded by preset name the
/// same way, so only preset pools round-trip: a custom pool would
/// either fail to load (unknown name) or — worse — silently reload as
/// the stock preset sharing its name, regenerating a *different* fleet.
/// Serialization therefore refuses (panics on) any pool that is not
/// byte-identical to its preset.
pub fn instance_to_json(inst: &Instance) -> Json {
    for p in &inst.pools {
        assert!(
            NodePool::parse(&p.name).as_ref() == Some(p),
            "only preset node pools round-trip through datasets; pool {:?} is custom \
             (or a modified preset) and would not reload identically",
            p.name
        );
    }
    let mut j = Json::obj();
    // `seed` (numeric) is kept for inspection; `seed_hex` is the
    // authoritative lossless form (JSON numbers are f64 — a full 64-bit
    // seed would round past 2^53, and the constrained-profile loader
    // regenerates from the seed).
    j.set("seed", inst.seed)
        .set("seed_hex", format!("{:016x}", inst.seed))
        .set("constraints", inst.profile.label())
        .set("node_pools", NodePool::mix_spec(&inst.pools))
        .set("nodes", inst.params.nodes)
        .set("pods_per_node", inst.params.pods_per_node)
        .set("priority_tiers", inst.params.priority_tiers)
        .set("usage", inst.params.usage)
        .set("node_cpu", inst.nodes[0].capacity.cpu)
        .set("node_ram", inst.nodes[0].capacity.ram);
    let rs: Vec<Json> = inst
        .replicasets
        .iter()
        .map(|r| {
            let mut o = Json::obj();
            o.set("replicas", r.replicas as u64)
                .set("cpu", r.template_request.cpu)
                .set("ram", r.template_request.ram)
                .set("priority", r.priority.0);
            o
        })
        .collect();
    j.set("replicasets", Json::Arr(rs));
    j
}

/// Rebuild an instance from JSON (pods re-expanded from ReplicaSets, so
/// arrival order and naming are preserved exactly). Instances recorded
/// with a constraint profile are re-derived through the deterministic
/// generator — `(params, seed, profile)` reproduces decorations
/// byte-for-byte; a missing `constraints` field means an (older)
/// unconstrained dataset.
pub fn instance_from_json(j: &Json) -> Result<Instance> {
    let get_i = |k: &str| -> Result<i64> {
        j.get(k)
            .and_then(Json::as_i64)
            .with_context(|| format!("missing field {k}"))
    };
    let params = GenParams {
        nodes: get_i("nodes")? as usize,
        pods_per_node: get_i("pods_per_node")? as usize,
        priority_tiers: get_i("priority_tiers")? as u32,
        usage: j
            .get("usage")
            .and_then(Json::as_f64)
            .context("missing usage")?,
    };
    let profile = match j.get("constraints").and_then(Json::as_str) {
        None => ConstraintProfile::None,
        Some(s) => ConstraintProfile::parse(s)
            .with_context(|| format!("unknown constraints profile {s:?}"))?,
    };
    let seed = match j.get("seed_hex").and_then(Json::as_str) {
        Some(h) => u64::from_str_radix(h, 16)
            .with_context(|| format!("bad seed_hex {h:?}"))?,
        None => get_i("seed")? as u64,
    };
    // Pool mixes are recorded by preset name and re-derived through the
    // deterministic generator, like constraint profiles (only preset
    // pools round-trip through datasets; missing field = identical
    // fleet, an older dataset).
    let pools = match j.get("node_pools").and_then(Json::as_str) {
        None | Some("") => Vec::new(),
        Some(s) => NodePool::parse_mix(s)
            .with_context(|| format!("unknown node_pools mix {s:?}"))?,
    };
    if profile != ConstraintProfile::None || !pools.is_empty() {
        return Ok(Instance::generate_pooled(params, seed, profile, &pools));
    }
    let cap = Resources::new(get_i("node_cpu")?, get_i("node_ram")?);
    let nodes = identical_nodes(params.nodes, cap);

    let mut replicasets = Vec::new();
    let mut pods: Vec<Pod> = Vec::new();
    let mut next_pod = 0u32;
    for (i, rj) in j
        .get("replicasets")
        .and_then(Json::as_arr)
        .context("missing replicasets")?
        .iter()
        .enumerate()
    {
        let gi = |k: &str| -> Result<i64> {
            rj.get(k)
                .and_then(Json::as_i64)
                .with_context(|| format!("rs {i}: missing {k}"))
        };
        let rs = ReplicaSet::new(
            i as u32,
            format!("rs-{i:03}"),
            gi("replicas")? as u32,
            Resources::new(gi("cpu")?, gi("ram")?),
            Priority(gi("priority")? as u32),
        );
        pods.extend(rs.expand(&mut next_pod));
        replicasets.push(rs);
    }

    Ok(Instance {
        params,
        seed,
        profile,
        pools,
        reference_capacity: cap,
        replicasets,
        pods,
        nodes,
    })
}

/// Save a dataset (one JSON document with an instance array).
pub fn save(instances: &[Instance], path: impl AsRef<Path>) -> Result<()> {
    let arr = Json::Arr(instances.iter().map(instance_to_json).collect());
    fs::write(path.as_ref(), arr.to_string_pretty())
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

/// Load a dataset.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<Instance>> {
    let text = fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let doc = parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    doc.as_arr()
        .context("dataset root must be an array")?
        .iter()
        .map(instance_from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_instance() {
        let inst = Instance::generate(
            GenParams {
                nodes: 4,
                pods_per_node: 4,
                priority_tiers: 4,
                usage: 0.95,
            },
            77,
        );
        let j = instance_to_json(&inst);
        let back = instance_from_json(&j).unwrap();
        assert_eq!(back.pods.len(), inst.pods.len());
        assert_eq!(back.nodes[0].capacity, inst.nodes[0].capacity);
        for (a, b) in inst.pods.iter().zip(&back.pods) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.request, b.request);
            assert_eq!(a.priority, b.priority);
            assert_eq!(a.owner, b.owner);
        }
    }

    #[test]
    fn constrained_roundtrip_rederives_decorations() {
        let params = GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 0.95,
        };
        // a full-width 64-bit seed: must survive the f64 JSON number
        // representation via seed_hex
        let inst =
            Instance::generate_constrained(params, 0xDEAD_BEEF_CAFE_F00D, ConstraintProfile::Mixed);
        let back = instance_from_json(&instance_to_json(&inst)).unwrap();
        assert_eq!(back.seed, inst.seed);
        assert_eq!(back.profile, ConstraintProfile::Mixed);
        assert_eq!(back.pods.len(), inst.pods.len());
        for (a, b) in inst.pods.iter().zip(&back.pods) {
            assert_eq!(a.request, b.request);
            assert_eq!(a.tolerations, b.tolerations);
            assert_eq!(a.anti_affinity, b.anti_affinity);
            assert_eq!(a.spread_max_skew, b.spread_max_skew);
            assert_eq!(a.extended, b.extended);
        }
        for (a, b) in inst.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.taints, b.taints);
            assert_eq!(a.extended, b.extended);
        }
    }

    #[test]
    #[should_panic(expected = "only preset node pools round-trip")]
    fn custom_pools_are_rejected_at_save_time() {
        // A modified preset would silently reload as the stock one
        // (different costs => different fleet); serialization refuses.
        let params = GenParams {
            nodes: 2,
            pods_per_node: 2,
            priority_tiers: 1,
            usage: 1.0,
        };
        let mut pricier = NodePool::small();
        pricier.cost += 1;
        let inst = Instance::generate_pooled(params, 5, ConstraintProfile::None, &[pricier]);
        instance_to_json(&inst);
    }

    #[test]
    fn pooled_roundtrip_rederives_the_heterogeneous_fleet() {
        let params = GenParams {
            nodes: 4,
            pods_per_node: 4,
            priority_tiers: 2,
            usage: 0.95,
        };
        let pools = NodePool::parse_mix("small,large,gpu").unwrap();
        let inst = Instance::generate_pooled(params, 99, ConstraintProfile::None, &pools);
        let back = instance_from_json(&instance_to_json(&inst)).unwrap();
        assert_eq!(back.pools, inst.pools);
        assert_eq!(back.reference_capacity, inst.reference_capacity);
        assert_eq!(back.nodes.len(), inst.nodes.len());
        for (a, b) in inst.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.capacity, b.capacity);
            assert_eq!(a.extended, b.extended);
            assert_eq!(a.name, b.name);
        }
        for (a, b) in inst.pods.iter().zip(&back.pods) {
            assert_eq!(a.request, b.request);
            assert_eq!(a.priority, b.priority);
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("kube-packd-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ds.json");
        let insts: Vec<Instance> = (0..3)
            .map(|s| {
                Instance::generate(
                    GenParams {
                        nodes: 4,
                        pods_per_node: 4,
                        priority_tiers: 1,
                        usage: 1.0,
                    },
                    s,
                )
            })
            .collect();
        save(&insts, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[1].pods.len(), insts[1].pods.len());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_malformed() {
        let dir = std::env::temp_dir().join("kube-packd-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"not\": \"an array\"}").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "[{\"seed\": 1}]").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
